//! The message-passing program IR executed by the simulator.
//!
//! A collective operation compiles (deterministically, per §3.2 — every
//! rank derives the same tree without communication) into a [`Program`]:
//! one ordered action list per rank. The engine executes actions in
//! per-rank program order; `Recv` blocks until the matching message
//! arrives.

use crate::error::{Error, Result};
use crate::netsim::payload::{Rank, ReduceOp};
use std::collections::HashMap;

/// Channel id of a `Mark` action (marks use no channel).
pub const NO_CHANNEL: u32 = u32::MAX;

/// Dense per-action channel resolution for a [`Program`], computed once
/// and reused across runs.
///
/// The engine's mailbox is keyed by `(from, to, tag)` channels. Hashing
/// that key on every send *and* every receive used to be the dominant
/// payload-independent cost of a warm run; since the channel set is a
/// pure function of the immutable program, it can be resolved ahead of
/// time into dense ids — cached plans and fused schedules carry their
/// index ([`crate::plan::CollectivePlan::channels`],
/// `Schedule::channels`), so warm executions hash nothing and index a
/// flat mailbox vector instead.
#[derive(Clone, Debug)]
pub struct ChannelIndex {
    /// `chan[r][i]` = channel id of rank `r`'s `i`-th action
    /// ([`NO_CHANNEL`] for `Mark`).
    chan: Vec<Vec<u32>>,
    /// Channel id → `(from, to, tag)`, for diagnostics.
    keys: Vec<(Rank, Rank, u64)>,
}

impl ChannelIndex {
    /// Resolve every send/recv of `prog` to a dense channel id. A send at
    /// rank `r` uses channel `(r, to, tag)`; a recv at `r` uses
    /// `(from, r, tag)` — matching sends and recvs share an id.
    pub fn build(prog: &Program) -> ChannelIndex {
        let mut ids: HashMap<(Rank, Rank, u64), u32> = HashMap::new();
        let mut keys: Vec<(Rank, Rank, u64)> = Vec::new();
        let mut chan = Vec::with_capacity(prog.n_ranks());
        for (r, list) in prog.actions.iter().enumerate() {
            let mut per_action = Vec::with_capacity(list.len());
            for a in list {
                let key = match a {
                    Action::Send { to, tag, .. } => (r, *to, *tag),
                    Action::Recv { from, tag, .. } => (*from, r, *tag),
                    Action::Mark { .. } => {
                        per_action.push(NO_CHANNEL);
                        continue;
                    }
                };
                let id = *ids.entry(key).or_insert_with(|| {
                    keys.push(key);
                    (keys.len() - 1) as u32
                });
                per_action.push(id);
            }
            chan.push(per_action);
        }
        ChannelIndex { chan, keys }
    }

    /// Number of distinct channels.
    pub fn n_channels(&self) -> usize {
        self.keys.len()
    }

    /// The `(from, to, tag)` key of channel `c`.
    pub fn key(&self, c: u32) -> (Rank, Rank, u64) {
        self.keys[c as usize]
    }

    /// Channel id of rank `r`'s `i`-th action.
    #[inline]
    pub fn at(&self, r: Rank, i: usize) -> u32 {
        self.chan[r][i]
    }

    /// Whether this index was built for a program of `prog`'s *shape*
    /// (rank count and per-rank action counts). This is the cheap O(1)
    /// guard the engine's indexed entry points apply per run; it cannot
    /// distinguish two different programs of coincident shape — for
    /// that, debug builds additionally run the exact
    /// [`ChannelIndex::consistent_with`] check, so tests catch a stale
    /// index while warm release runs stay hash-free.
    pub fn matches(&self, prog: &Program) -> bool {
        self.chan.len() == prog.n_ranks()
            && self.chan.iter().zip(&prog.actions).all(|(c, a)| c.len() == a.len())
    }

    /// Exact consistency check: every action's resolved channel key
    /// equals the key the action actually names. O(total actions) — the
    /// engine runs it under `debug_assert!` only.
    pub fn consistent_with(&self, prog: &Program) -> bool {
        if !self.matches(prog) {
            return false;
        }
        for (r, list) in prog.actions.iter().enumerate() {
            for (i, a) in list.iter().enumerate() {
                let id = self.chan[r][i];
                let ok = match a {
                    Action::Send { to, tag, .. } => {
                        id != NO_CHANNEL && self.keys[id as usize] == (r, *to, *tag)
                    }
                    Action::Recv { from, tag, .. } => {
                        id != NO_CHANNEL && self.keys[id as usize] == (*from, r, *tag)
                    }
                    Action::Mark { .. } => id == NO_CHANNEL,
                };
                if !ok {
                    return false;
                }
            }
        }
        true
    }

    /// Approximate resident size (for plan footprint accounting).
    pub fn approx_bytes(&self) -> usize {
        let per_rank = std::mem::size_of::<Vec<u32>>();
        self.chan.iter().map(|v| v.len() * 4 + per_rank).sum::<usize>()
            + self.keys.len() * std::mem::size_of::<(Rank, Rank, u64)>()
    }
}

/// What a `Send` puts on the wire, taken from the sender's payload register.
#[derive(Clone, Debug, PartialEq)]
pub enum SendPart {
    /// The whole payload (bcast forwarding, reduce partials, gather-up).
    All,
    /// Only the listed ranks' segments (scatter-down).
    Ranks(Vec<Rank>),
    /// Only the segments whose keys fall in one of the sorted, disjoint
    /// half-open `[lo, hi)` intervals — the O(runs) alternative to
    /// [`SendPart::Ranks`] for subtree/complement routing: rank sets of
    /// topology-aware subtrees coalesce to a handful of contiguous runs,
    /// so this stores (and selects) intervals instead of O(n) rank lists.
    Ranges(Vec<(Rank, Rank)>),
    /// Zero-byte control message (barrier).
    Empty,
}

/// How a `Recv` folds the incoming payload into the local register.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Merge {
    /// Overwrite (bcast, scatter).
    Replace,
    /// Disjoint union of segments (gather).
    Union,
    /// Elementwise reduction via the combiner (reduce). Charges combine
    /// compute time in addition to the receive.
    Combine(ReduceOp),
    /// Ignore the payload (barrier control messages).
    Discard,
}

/// One step of a rank's program.
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    Send { to: Rank, tag: u64, part: SendPart },
    Recv { from: Rank, tag: u64, merge: Merge },
    /// Zero-cost boundary marker: records the rank's local clock under
    /// `id` when reached. Fused schedules insert one per rank at each
    /// segment boundary so a single run yields per-segment completion
    /// timestamps (`SimResult::mark_times_us`). Not a synchronization
    /// point — ranks pass it independently.
    Mark { id: u64 },
}

/// Per-rank action lists.
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub actions: Vec<Vec<Action>>,
}

impl Program {
    pub fn new(n_ranks: usize) -> Self {
        Program { actions: vec![Vec::new(); n_ranks] }
    }

    pub fn n_ranks(&self) -> usize {
        self.actions.len()
    }

    pub fn send(&mut self, from: Rank, to: Rank, tag: u64, part: SendPart) {
        self.actions[from].push(Action::Send { to, tag, part });
    }

    pub fn recv(&mut self, at: Rank, from: Rank, tag: u64, merge: Merge) {
        self.actions[at].push(Action::Recv { from, tag, merge });
    }

    /// Append a boundary marker at `rank`.
    pub fn mark(&mut self, rank: Rank, id: u64) {
        self.actions[rank].push(Action::Mark { id });
    }

    /// Append a boundary marker with the same `id` at every rank.
    pub fn mark_all(&mut self, id: u64) {
        for list in &mut self.actions {
            list.push(Action::Mark { id });
        }
    }

    pub fn total_actions(&self) -> usize {
        self.actions.iter().map(|a| a.len()).sum()
    }

    /// Static sanity checks, independent of execution:
    /// - peers in range,
    /// - no self-messages (collective trees never need them),
    /// - every `(from,to,tag)` send count matches the recv count.
    ///
    /// (Deadlock-freedom is a dynamic property; the engine detects it.)
    pub fn validate(&self) -> Result<()> {
        let n = self.n_ranks();
        let mut sends: HashMap<(Rank, Rank, u64), i64> = HashMap::new();
        for (r, list) in self.actions.iter().enumerate() {
            for a in list {
                match a {
                    Action::Send { to, tag, .. } => {
                        if *to >= n {
                            return Err(Error::Schedule(format!(
                                "rank {r} sends to out-of-range rank {to}"
                            )));
                        }
                        if *to == r {
                            return Err(Error::Schedule(format!("rank {r} sends to itself")));
                        }
                        *sends.entry((r, *to, *tag)).or_insert(0) += 1;
                    }
                    Action::Recv { from, tag, .. } => {
                        if *from >= n {
                            return Err(Error::Schedule(format!(
                                "rank {r} receives from out-of-range rank {from}"
                            )));
                        }
                        if *from == r {
                            return Err(Error::Schedule(format!("rank {r} receives from itself")));
                        }
                        *sends.entry((*from, r, *tag)).or_insert(0) -= 1;
                    }
                    Action::Mark { .. } => {}
                }
            }
        }
        for ((f, t, tag), bal) in sends {
            if bal != 0 {
                return Err(Error::Schedule(format!(
                    "unbalanced channel {f}->{t} tag {tag}: send-recv imbalance {bal}"
                )));
            }
        }
        Ok(())
    }

    /// Shift every send/recv tag by `delta`, in place.
    ///
    /// This is the pipeline's cheap alternative to recompilation: a cached
    /// [`Program`] is compiled once at a fixed base tag, and composing it
    /// into a larger program (e.g. allreduce = cached reduce ; cached
    /// bcast) only requires rebasing the second phase's tags so the two
    /// phases' channels stay disjoint — an O(actions) integer add instead
    /// of an O(tree) rebuild + recompile.
    pub fn rebase_tags(&mut self, delta: u64) {
        for list in &mut self.actions {
            for a in list {
                match a {
                    Action::Send { tag, .. } => *tag += delta,
                    Action::Recv { tag, .. } => *tag += delta,
                    Action::Mark { .. } => {} // marker ids are not tags
                }
            }
        }
    }

    /// Copy of this program with every tag shifted by `delta`
    /// (non-destructive [`Program::rebase_tags`]).
    pub fn rebased(&self, delta: u64) -> Program {
        let mut p = self.clone();
        p.rebase_tags(delta);
        p
    }

    /// Largest tag used by any action (0 for an empty program). A safe
    /// rebase delta for sequential composition is `max_tag() + 1`.
    pub fn max_tag(&self) -> u64 {
        self.actions
            .iter()
            .flatten()
            .filter_map(|a| match a {
                Action::Send { tag, .. } | Action::Recv { tag, .. } => Some(*tag),
                Action::Mark { .. } => None,
            })
            .max()
            .unwrap_or(0)
    }

    /// Append another program's actions (sequential composition with
    /// distinct tags, e.g. allreduce = reduce ; bcast).
    pub fn then(&mut self, other: Program) -> Result<()> {
        if other.n_ranks() != self.n_ranks() {
            return Err(Error::Schedule(format!(
                "program composition rank mismatch: {} vs {}",
                self.n_ranks(),
                other.n_ranks()
            )));
        }
        for (mine, theirs) in self.actions.iter_mut().zip(other.actions) {
            mine.extend(theirs);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_program_validates() {
        let mut p = Program::new(2);
        p.send(0, 1, 7, SendPart::All);
        p.recv(1, 0, 7, Merge::Replace);
        assert!(p.validate().is_ok());
        assert_eq!(p.total_actions(), 2);
    }

    #[test]
    fn unbalanced_send_rejected() {
        let mut p = Program::new(2);
        p.send(0, 1, 7, SendPart::All);
        assert!(p.validate().is_err());
    }

    #[test]
    fn tag_mismatch_rejected() {
        let mut p = Program::new(2);
        p.send(0, 1, 7, SendPart::All);
        p.recv(1, 0, 8, Merge::Replace);
        assert!(p.validate().is_err());
    }

    #[test]
    fn self_message_rejected() {
        let mut p = Program::new(2);
        p.send(0, 0, 1, SendPart::All);
        p.recv(0, 0, 1, Merge::Replace);
        assert!(p.validate().is_err());
    }

    #[test]
    fn out_of_range_rejected() {
        let mut p = Program::new(2);
        p.send(0, 5, 1, SendPart::All);
        assert!(p.validate().is_err());
    }

    #[test]
    fn rebase_shifts_all_tags() {
        let mut p = Program::new(2);
        p.send(0, 1, 3, SendPart::All);
        p.recv(1, 0, 3, Merge::Replace);
        assert_eq!(p.max_tag(), 3);
        let r = p.rebased(10);
        assert_eq!(r.max_tag(), 13);
        assert!(r.validate().is_ok());
        // original untouched
        assert_eq!(p.max_tag(), 3);
        // composing a program with its own rebased copy keeps channels
        // disjoint (the cached-plan composition pattern).
        let delta = p.max_tag() + 1;
        let second = p.rebased(delta);
        p.then(second).unwrap();
        assert!(p.validate().is_ok());
        assert_eq!(p.actions[0].len(), 2);
    }

    #[test]
    fn marks_are_tag_neutral_and_validate() {
        let mut p = Program::new(2);
        p.send(0, 1, 5, SendPart::All);
        p.recv(1, 0, 5, Merge::Replace);
        p.mark_all(0);
        p.mark(0, 1);
        assert!(p.validate().is_ok());
        assert_eq!(p.max_tag(), 5, "marker ids never count as tags");
        let r = p.rebased(10);
        assert_eq!(r.max_tag(), 15);
        assert!(
            r.actions[0].contains(&Action::Mark { id: 1 }),
            "rebase leaves marker ids untouched"
        );
        assert_eq!(p.total_actions(), 5);
    }

    #[test]
    fn channel_index_pairs_sends_with_recvs() {
        let mut p = Program::new(3);
        p.send(0, 1, 7, SendPart::All);
        p.recv(1, 0, 7, Merge::Replace);
        p.mark_all(0);
        p.send(1, 2, 7, SendPart::All);
        p.recv(2, 1, 7, Merge::Replace);
        let ix = ChannelIndex::build(&p);
        assert!(ix.matches(&p));
        assert_eq!(ix.n_channels(), 2);
        // matching send/recv share an id; distinct channels differ.
        // (rank 2's action 0 is its mark_all marker, the recv is at 1)
        assert_eq!(ix.at(0, 0), ix.at(1, 0));
        assert_eq!(ix.at(1, 2), ix.at(2, 1));
        assert_ne!(ix.at(0, 0), ix.at(1, 2));
        assert_eq!(ix.at(2, 0), NO_CHANNEL);
        assert_eq!(ix.key(ix.at(0, 0)), (0, 1, 7));
        assert_eq!(ix.key(ix.at(1, 2)), (1, 2, 7));
        // marks carry no channel
        assert_eq!(ix.at(0, 1), NO_CHANNEL);
        assert!(ix.approx_bytes() > 0);
        assert!(ix.consistent_with(&p));
        // a different shape no longer matches
        let q = Program::new(2);
        assert!(!ix.matches(&q));
        assert!(!ix.consistent_with(&q));
        // a different program of coincident shape passes the cheap shape
        // check but fails the exact consistency check
        let mut rev = Program::new(3);
        rev.send(0, 2, 7, SendPart::All);
        rev.recv(1, 2, 7, Merge::Replace);
        rev.mark_all(0);
        rev.send(1, 0, 7, SendPart::All);
        rev.recv(2, 0, 7, Merge::Replace);
        assert!(ix.matches(&rev), "same shape");
        assert!(!ix.consistent_with(&rev), "different channels");
    }

    #[test]
    fn composition_concatenates() {
        let mut a = Program::new(2);
        a.send(0, 1, 1, SendPart::All);
        a.recv(1, 0, 1, Merge::Replace);
        let mut b = Program::new(2);
        b.send(1, 0, 2, SendPart::All);
        b.recv(0, 1, 2, Merge::Replace);
        a.then(b).unwrap();
        assert_eq!(a.actions[0].len(), 2);
        assert!(a.validate().is_ok());
        let c = Program::new(3);
        assert!(a.then(c).is_err());
    }
}
