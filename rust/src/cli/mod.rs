//! Minimal CLI argument parser (no `clap` in the offline vendor set):
//! `--key value` / `--key=value` flags, bare `--switch`es, positionals.

use crate::error::{Error, Result};
use std::collections::{HashMap, HashSet};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: HashSet<String>,
}

/// Flags that take a value (everything else beginning `--` is a switch).
pub const VALUE_FLAGS: &[&str] = &[
    "sizes", "size", "steps", "lr", "strategy", "root", "spec", "sites", "machines", "procs",
    "out", "artifacts", "seed", "shape", "params", "algo", "op", "boundary", "save",
    "policy-file", "threads", "chunks", "order", "mode", "matrix", "noise", "probe", "connect",
    "socket", "tcp", "policy-dir", "kind",
];

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Result<Args> {
        let mut a = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if VALUE_FLAGS.contains(&stripped) {
                    let v = iter
                        .next()
                        .ok_or_else(|| Error::Cli(format!("--{stripped} needs a value")))?;
                    a.flags.insert(stripped.to_string(), v);
                } else {
                    a.switches.insert(stripped.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| Error::Cli(format!("--{key}: '{v}' is not an integer")))
            }
        }
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| Error::Cli(format!("--{key}: '{v}' is not a float"))),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.contains(switch)
    }

    /// Parse a single `--size` value with `k`/`m` suffix support.
    pub fn get_size(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => parse_size(v),
        }
    }

    /// Parse `--sizes 1024,4096,...` (supports `k`/`m` suffixes).
    pub fn sizes(&self, default: &[usize]) -> Result<Vec<usize>> {
        match self.get("sizes") {
            None => Ok(default.to_vec()),
            Some(v) => v.split(',').map(parse_size).collect(),
        }
    }

    /// Parse a strategy name.
    pub fn strategy(&self, default: crate::tree::Strategy) -> Result<crate::tree::Strategy> {
        use crate::tree::Strategy::*;
        match self.get("strategy") {
            None => Ok(default),
            Some("unaware") | Some("mpich-binomial") | Some("binomial") => Ok(Unaware),
            Some("machine") | Some("magpie-machine") => Ok(TwoLevelMachine),
            Some("site") | Some("magpie-site") => Ok(TwoLevelSite),
            Some("multilevel") => Ok(Multilevel),
            Some(other) => Err(Error::Cli(format!(
                "unknown strategy '{other}' (use unaware|machine|site|multilevel)"
            ))),
        }
    }

    /// Parse `--algo` (uniform allreduce composition).
    pub fn allreduce_algo(
        &self,
        default: crate::plan::AllreduceAlgo,
    ) -> Result<crate::plan::AllreduceAlgo> {
        use crate::plan::AllreduceAlgo::*;
        match self.get("algo") {
            None => Ok(default),
            Some("rb") | Some("reduce-bcast") | Some("reduce+bcast") => Ok(ReduceBcast),
            Some("rsag") | Some("rs+ag") | Some("reduce-scatter-allgather") => {
                Ok(ReduceScatterAllgather)
            }
            Some(other) => {
                Err(Error::Cli(format!("unknown allreduce algo '{other}' (use rb|rsag)")))
            }
        }
    }

    /// Parse `--algo`, `--boundary`, `--chunks` and `--order` into an
    /// allreduce [`AlgoPolicy`]: `rb`/`rsag` are uniform compositions,
    /// `hybrid` pairs with `--boundary N` (default 1 = reduce+bcast
    /// across the WAN only), and `comp:rb,halving,ring` assigns one
    /// level algorithm per separation level, outermost (WAN) first, the
    /// last entry repeating for any deeper levels. `--chunks K` splits
    /// each delivery into `K` pipelined pieces per edge and `--order
    /// fifo|scf|ll` picks their schedule. Flags that would otherwise be
    /// silently dropped are rejected instead: `--boundary` without
    /// `--algo hybrid`, `--order` without `--chunks >= 2`.
    pub fn algo_policy(
        &self,
        default: crate::plan::AlgoPolicy,
    ) -> Result<crate::plan::AlgoPolicy> {
        use crate::plan::{AlgoPolicy, AllreduceAlgo, ChunkOrder, LevelAlgo, MAX_CHUNKS};
        let structural = match self.get("algo") {
            Some("hybrid") => AlgoPolicy::hybrid(self.get_usize("boundary", 1)?),
            algo => {
                if self.get("boundary").is_some() {
                    return Err(Error::Cli(
                        "--boundary only applies to --algo hybrid".into(),
                    ));
                }
                match algo {
                    None => default,
                    Some("rb") | Some("reduce-bcast") | Some("reduce+bcast") => {
                        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast)
                    }
                    Some("rsag") | Some("rs+ag") | Some("reduce-scatter-allgather") => {
                        AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather)
                    }
                    Some(spec) if spec.starts_with("comp:") => {
                        let algos = spec["comp:".len()..]
                            .split(',')
                            .map(|name| {
                                LevelAlgo::from_name(name.trim()).ok_or_else(|| {
                                    Error::Cli(format!(
                                        "unknown level algorithm '{name}' in '{spec}' \
                                         (use rb|ring|halving|binomial|flat)"
                                    ))
                                })
                            })
                            .collect::<Result<Vec<_>>>()?;
                        AlgoPolicy::composition(&algos)?
                    }
                    Some(other) => {
                        return Err(Error::Cli(format!(
                            "unknown allreduce algo '{other}' \
                             (use rb|rsag|hybrid|comp:a,b,...)"
                        )))
                    }
                }
            }
        };
        let chunks = self.get_usize("chunks", 1)?;
        if chunks < 1 || chunks > MAX_CHUNKS {
            return Err(Error::Cli(format!("--chunks must be in 1..={MAX_CHUNKS}, got {chunks}")));
        }
        let order = match self.get("order") {
            None => ChunkOrder::Fifo,
            Some(name) => {
                if chunks <= 1 {
                    return Err(Error::Cli("--order only applies with --chunks >= 2".into()));
                }
                ChunkOrder::from_name(name).ok_or_else(|| {
                    Error::Cli(format!("unknown chunk order '{name}' (use fifo|scf|ll)"))
                })?
            }
        };
        Ok(structural.with_chunks(chunks).with_chunk_order(order))
    }

    /// Parse `--algo`/`--boundary`/`--chunks`/`--order` into an
    /// *optional* policy pin: `None` when none of the flags is given
    /// (let the session's policy provider resolve — the `--policy-file`
    /// path), `Some(policy)` when the user pinned one explicitly.
    /// Invalid flag combinations are still rejected.
    pub fn algo_policy_opt(&self) -> Result<Option<crate::plan::AlgoPolicy>> {
        if ["algo", "boundary", "chunks", "order"].iter().all(|k| self.get(k).is_none()) {
            return Ok(None);
        }
        self.algo_policy(crate::plan::AlgoPolicy::uniform(
            crate::plan::AllreduceAlgo::ReduceBcast,
        ))
        .map(Some)
    }

    /// Parse `--mode auto|exhaustive|beam|beam:W` into a composition
    /// tuner [`crate::coordinator::SearchMode`] (default `Auto`:
    /// exhaustive up to 3 separation levels, beam search with the
    /// default width beyond).
    pub fn search_mode(&self) -> Result<crate::coordinator::SearchMode> {
        use crate::coordinator::{SearchMode, DEFAULT_BEAM_WIDTH};
        match self.get("mode") {
            None | Some("auto") => Ok(SearchMode::Auto),
            Some("exhaustive") | Some("full") => Ok(SearchMode::Exhaustive),
            Some("beam") => Ok(SearchMode::Beam { width: DEFAULT_BEAM_WIDTH }),
            Some(spec) => match spec.strip_prefix("beam:").map(str::parse::<usize>) {
                Some(Ok(w)) if w >= 1 => Ok(SearchMode::Beam { width: w }),
                _ => Err(Error::Cli(format!(
                    "unknown search mode '{spec}' (use auto|exhaustive|beam|beam:W)"
                ))),
            },
        }
    }

    /// Parse `--threads N` into an execution mode: absent or `<= 1`
    /// means sequential; `N > 1` selects the cluster-sharded engine
    /// (bitwise-identical results, parallel wall-clock).
    pub fn exec_mode(&self) -> Result<crate::netsim::ExecMode> {
        let threads = self.get_usize("threads", 1)?;
        Ok(if threads > 1 {
            crate::netsim::ExecMode::Sharded { threads }
        } else {
            crate::netsim::ExecMode::Sequential
        })
    }

    /// Parse `--op` (reduction operator).
    pub fn reduce_op(
        &self,
        default: crate::netsim::ReduceOp,
    ) -> Result<crate::netsim::ReduceOp> {
        use crate::netsim::ReduceOp::*;
        match self.get("op") {
            None => Ok(default),
            Some("sum") => Ok(Sum),
            Some("max") => Ok(Max),
            Some("min") => Ok(Min),
            Some("prod") => Ok(Prod),
            Some(other) => {
                Err(Error::Cli(format!("unknown reduce op '{other}' (use sum|max|min|prod)")))
            }
        }
    }
}

/// `"64k"` -> 65536, `"2m"` -> 2097152, plain integers pass through.
pub fn parse_size(s: &str) -> Result<usize> {
    let s = s.trim().to_lowercase();
    let (num, mult) = if let Some(p) = s.strip_suffix('m') {
        (p, 1024 * 1024)
    } else if let Some(p) = s.strip_suffix('k') {
        (p, 1024)
    } else {
        (s.as_str(), 1)
    };
    num.parse::<usize>()
        .map(|v| v * mult)
        .map_err(|_| Error::Cli(format!("bad size '{s}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn parses_flags_switches_positionals() {
        let a = args("fig8 --sizes 1k,64k --xla --root=5");
        assert_eq!(a.positional, vec!["fig8"]);
        assert_eq!(a.get("sizes"), Some("1k,64k"));
        assert!(a.has("xla"));
        assert_eq!(a.get_usize("root", 0).unwrap(), 5);
    }

    #[test]
    fn sizes_parsing() {
        assert_eq!(parse_size("1024").unwrap(), 1024);
        assert_eq!(parse_size("64k").unwrap(), 65536);
        assert_eq!(parse_size("2M").unwrap(), 2 * 1024 * 1024);
        assert!(parse_size("x").is_err());
        let a = args("--sizes 1k,2k");
        assert_eq!(a.sizes(&[]).unwrap(), vec![1024, 2048]);
        let b = args("");
        assert_eq!(b.sizes(&[7]).unwrap(), vec![7]);
    }

    #[test]
    fn strategy_names() {
        use crate::tree::Strategy;
        assert_eq!(args("--strategy site").strategy(Strategy::Unaware).unwrap(),
            Strategy::TwoLevelSite);
        assert_eq!(args("").strategy(Strategy::Multilevel).unwrap(), Strategy::Multilevel);
        assert!(args("--strategy bogus").strategy(Strategy::Unaware).is_err());
    }

    #[test]
    fn allreduce_algo_and_op_names() {
        use crate::netsim::ReduceOp;
        use crate::plan::AllreduceAlgo;
        assert_eq!(
            args("--algo rsag").allreduce_algo(AllreduceAlgo::ReduceBcast).unwrap(),
            AllreduceAlgo::ReduceScatterAllgather
        );
        assert_eq!(
            args("").allreduce_algo(AllreduceAlgo::ReduceBcast).unwrap(),
            AllreduceAlgo::ReduceBcast
        );
        assert!(args("--algo bogus").allreduce_algo(AllreduceAlgo::ReduceBcast).is_err());
        assert_eq!(args("--op max").reduce_op(ReduceOp::Sum).unwrap(), ReduceOp::Max);
        assert!(args("--op bogus").reduce_op(ReduceOp::Sum).is_err());
    }

    #[test]
    fn algo_policy_names() {
        use crate::plan::{AlgoPolicy, AllreduceAlgo};
        let rb = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast);
        assert_eq!(args("").algo_policy(rb).unwrap(), rb);
        assert_eq!(
            args("--algo rsag").algo_policy(rb).unwrap(),
            AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather)
        );
        assert_eq!(args("--algo hybrid").algo_policy(rb).unwrap(), AlgoPolicy::hybrid(1));
        assert_eq!(
            args("--algo hybrid --boundary 2").algo_policy(rb).unwrap(),
            AlgoPolicy::hybrid(2)
        );
        assert!(args("--algo bogus").algo_policy(rb).is_err());
        assert!(args("--algo hybrid --boundary x").algo_policy(rb).is_err());
        // --boundary without --algo hybrid would silently change the
        // measured composition; reject it instead.
        assert!(args("--boundary 2").algo_policy(rb).is_err());
        assert!(args("--algo rsag --boundary 2").algo_policy(rb).is_err());
    }

    #[test]
    fn algo_policy_opt_defers_to_the_provider() {
        use crate::plan::{AlgoPolicy, AllreduceAlgo};
        assert_eq!(args("").algo_policy_opt().unwrap(), None, "no pin: provider resolves");
        assert_eq!(
            args("--algo rsag").algo_policy_opt().unwrap(),
            Some(AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather))
        );
        assert_eq!(
            args("--algo hybrid --boundary 2").algo_policy_opt().unwrap(),
            Some(AlgoPolicy::hybrid(2))
        );
        assert!(args("--boundary 2").algo_policy_opt().is_err());
        // --save / --policy-file take values, not switch semantics.
        let a = args("tune-boundary --save t.json");
        assert_eq!(a.get("save"), Some("t.json"));
        let a = args("train --policy-file t.json");
        assert_eq!(a.get("policy-file"), Some("t.json"));
    }

    #[test]
    fn composition_algo_and_chunk_flags() {
        use crate::plan::{AlgoPolicy, AllreduceAlgo, ChunkOrder, LevelAlgo};
        let rb = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast);
        assert_eq!(
            args("--algo comp:rb,halving,ring").algo_policy(rb).unwrap(),
            AlgoPolicy::composition(&[
                LevelAlgo::ReduceBcast,
                LevelAlgo::Halving,
                LevelAlgo::RsAgRing
            ])
            .unwrap()
        );
        assert_eq!(
            args("--algo comp:ring --chunks 4").algo_policy(rb).unwrap(),
            AlgoPolicy::uniform_level(LevelAlgo::RsAgRing).with_chunks(4)
        );
        assert_eq!(
            args("--algo rb --chunks 4 --order scf").algo_policy(rb).unwrap(),
            rb.with_chunks(4).with_chunk_order(ChunkOrder::ShortestFirst)
        );
        assert_eq!(
            args("--algo rb --chunks 4 --order ll").algo_policy(rb).unwrap(),
            rb.with_chunks(4).with_chunk_order(ChunkOrder::LeastLoaded)
        );
        // Chunking composes with the default policy too — and counts as
        // an explicit pin for the optional form.
        assert_eq!(args("--chunks 2").algo_policy(rb).unwrap(), rb.with_chunks(2));
        assert_eq!(args("--chunks 2").algo_policy_opt().unwrap(), Some(rb.with_chunks(2)));
        assert!(args("--algo comp:rb,bogus").algo_policy(rb).is_err());
        assert!(args("--algo comp:").algo_policy(rb).is_err());
        assert!(args("--chunks 0").algo_policy(rb).is_err());
        assert!(args("--chunks 999").algo_policy(rb).is_err());
        assert!(args("--order scf").algo_policy(rb).is_err(), "order without chunks");
        assert!(args("--algo rb --chunks 4 --order bogus").algo_policy(rb).is_err());
    }

    #[test]
    fn search_mode_names() {
        use crate::coordinator::{SearchMode, DEFAULT_BEAM_WIDTH};
        assert_eq!(args("").search_mode().unwrap(), SearchMode::Auto);
        assert_eq!(args("--mode auto").search_mode().unwrap(), SearchMode::Auto);
        assert_eq!(args("--mode exhaustive").search_mode().unwrap(), SearchMode::Exhaustive);
        assert_eq!(
            args("--mode beam").search_mode().unwrap(),
            SearchMode::Beam { width: DEFAULT_BEAM_WIDTH }
        );
        assert_eq!(args("--mode beam:4").search_mode().unwrap(), SearchMode::Beam { width: 4 });
        assert!(args("--mode beam:0").search_mode().is_err());
        assert!(args("--mode bogus").search_mode().is_err());
    }

    #[test]
    fn missing_value_flag_errors() {
        assert!(Args::parse(vec!["--sizes".to_string()]).is_err());
    }

    #[test]
    fn threads_flag_selects_the_exec_mode() {
        use crate::netsim::ExecMode;
        assert_eq!(args("").exec_mode().unwrap(), ExecMode::Sequential);
        assert_eq!(args("--threads 1").exec_mode().unwrap(), ExecMode::Sequential);
        assert_eq!(args("--threads 4").exec_mode().unwrap(), ExecMode::Sharded { threads: 4 });
        assert!(args("--threads x").exec_mode().is_err());
        assert!(Args::parse(vec!["--threads".to_string()]).is_err(), "takes a value");
    }

    #[test]
    fn numeric_parsing_errors() {
        assert!(args("--steps nope").get_usize("steps", 1).is_err());
        assert!(args("--lr nope").get_f32("lr", 0.1).is_err());
        assert_eq!(args("").get_usize("steps", 9).unwrap(), 9);
    }
}
