//! The typed collective-request path: one value per call, one generic
//! engine entry point.
//!
//! Every collective is described by a **request** — a small struct
//! borrowing the caller's inputs — that implements [`OpSpec`]. The spec
//! answers the four questions the pipeline asks of any operation:
//!
//! 1. *which plan?* — [`OpSpec::op_kind`] (+ root + segments) keys the
//!    [`crate::plan::PlanCache`] lookup;
//! 2. *which program?* — [`OpSpec::compile`] lowers a tree to simulator
//!    IR (the plan cache calls the same total dispatch, so specs never
//!    bypass memoization);
//! 3. *which bytes in?* — [`OpSpec::encode_init`] validates inputs and
//!    builds the per-rank initial payload registers;
//! 4. *which data out?* — [`OpSpec::decode`] extracts per-rank results
//!    from the finished [`SimResult`] (and [`OpSpec::bytes_model`]
//!    predicts traffic statically where well-defined).
//!
//! [`crate::collectives::CollectiveEngine::run`] is the single generic
//! driver: `plan_for(spec) → encode → simulate → decode`. The engine's
//! named methods (`bcast`, `reduce`, …) are thin wrappers constructing
//! these requests, so a new operation is a new `OpSpec` impl — not an
//! eleventh hand-rolled engine method duplicating payload construction,
//! validation and result extraction.

use crate::error::{Error, Result};
use crate::netsim::{GhostPayload, Payload, Program, ReduceOp, SimResult};
use crate::plan::{AlgoPolicy, BytesModel, OpKind};
use crate::topology::{Clustering, Communicator, Rank};
use crate::tree::Tree;

use super::extended::a2a_key;

/// A typed collective request: everything one call needs, in one value.
///
/// Implementations are cheap, borrow their inputs, and are consumed by
/// [`crate::collectives::CollectiveEngine::run`] /
/// [`crate::collectives::CollectiveEngine::run_sim`].
pub trait OpSpec {
    /// Which plan this request compiles to (cache-key component).
    fn op_kind(&self) -> OpKind;

    /// Tree root the plan is built at (cache-key component).
    fn root(&self) -> Rank {
        0
    }

    /// Pipelining chunk count (cache-key component; 1 = unsegmented).
    fn segments(&self) -> usize {
        1
    }

    /// Validate the inputs and build every rank's initial payload
    /// register.
    fn encode_init(&self, comm: &Communicator) -> Result<Vec<Payload>>;

    /// Ghost (timing-only) initial registers: the per-key *lengths* of
    /// exactly what [`OpSpec::encode_init`] would build, for
    /// `CollectiveEngine::simulate_timing`. The default derives them by
    /// materializing the full payloads and stripping the data — correct
    /// for every spec by construction. Timing-hot specs override it with
    /// pure integer constructions that allocate no payload data.
    fn encode_ghost(&self, comm: &Communicator) -> Result<Vec<GhostPayload>> {
        Ok(self.encode_init(comm)?.iter().map(GhostPayload::of).collect())
    }

    /// Extract the per-rank result data from a finished simulation.
    fn decode(&self, comm: &Communicator, sim: &SimResult) -> Result<Vec<Vec<f32>>>;

    /// Lower a communication tree to the simulator program implementing
    /// this op — the same total dispatch the plan cache compiles through,
    /// so a spec's program and its cached plan can never drift.
    fn compile(&self, clustering: &Clustering, tree: &Tree, tag: u64) -> Result<Program> {
        self.op_kind().compile(clustering, tree, self.segments(), tag)
    }

    /// Static byte-prediction model (see [`BytesModel`]).
    fn bytes_model(&self) -> BytesModel {
        self.op_kind().bytes_model()
    }

    /// Display name.
    fn name(&self) -> &'static str {
        self.op_kind().name()
    }
}

/// Equal-count, equal-length contribution validation shared by the
/// reduction-style requests.
fn check_contribs(comm: &Communicator, contributions: &[Vec<f32>]) -> Result<()> {
    if contributions.len() != comm.size() {
        return Err(Error::Comm(format!(
            "{} contributions for {} ranks",
            contributions.len(),
            comm.size()
        )));
    }
    let len = contributions[0].len();
    if contributions.iter().any(|c| c.len() != len) {
        return Err(Error::Comm("ragged contributions".into()));
    }
    Ok(())
}

/// Split `len` elements into `n` contiguous chunks (ceil-sized; trailing
/// chunks may be empty). Every rank derives identical bounds, so chunk
/// `q` is globally consistent — the §3.2 determinism requirement applied
/// to payload segmentation.
pub(crate) fn chunk_ranges(len: usize, n: usize) -> Vec<(usize, usize)> {
    let n = n.max(1);
    let chunk = len.div_ceil(n);
    (0..n)
        .map(|q| ((q * chunk).min(len), ((q + 1) * chunk).min(len)))
        .collect()
}

/// MPI_Bcast: `data` flows from `root` to every rank.
/// Decoded `data[r]` = the buffer received at rank `r`.
pub struct Bcast<'a> {
    pub root: Rank,
    pub data: &'a [f32],
}

impl OpSpec for Bcast<'_> {
    fn op_kind(&self) -> OpKind {
        OpKind::Bcast
    }

    fn root(&self) -> Rank {
        self.root
    }

    fn encode_init(&self, comm: &Communicator) -> Result<Vec<Payload>> {
        let mut init = vec![Payload::empty(); comm.size()];
        init[self.root] = Payload::single(self.root, self.data.to_vec());
        Ok(init)
    }

    fn encode_ghost(&self, comm: &Communicator) -> Result<Vec<GhostPayload>> {
        let mut init = vec![GhostPayload::empty(); comm.size()];
        init[self.root] = GhostPayload::single(self.root, self.data.len());
        Ok(init)
    }

    fn decode(&self, comm: &Communicator, sim: &SimResult) -> Result<Vec<Vec<f32>>> {
        Ok((0..comm.size())
            .map(|r| sim.payloads[r].get_cloned(&self.root).unwrap_or_default())
            .collect())
    }
}

/// MPI_Reduce: elementwise `op` over every rank's contribution, result
/// at `root`. Decoded `data[root]` = the reduced vector (non-roots hold
/// their partials; MPI leaves them undefined).
pub struct Reduce<'a> {
    pub root: Rank,
    pub op: ReduceOp,
    pub contributions: &'a [Vec<f32>],
}

impl OpSpec for Reduce<'_> {
    fn op_kind(&self) -> OpKind {
        OpKind::Reduce(self.op)
    }

    fn root(&self) -> Rank {
        self.root
    }

    fn encode_init(&self, comm: &Communicator) -> Result<Vec<Payload>> {
        check_contribs(comm, self.contributions)?;
        let init: Vec<Payload> = self
            .contributions
            .iter()
            .map(|c| Payload::single(0, c.clone()))
            .collect();
        Ok(init)
    }

    fn encode_ghost(&self, comm: &Communicator) -> Result<Vec<GhostPayload>> {
        check_contribs(comm, self.contributions)?;
        let len = self.contributions[0].len();
        Ok(vec![GhostPayload::single(0, len); comm.size()])
    }

    fn decode(&self, comm: &Communicator, sim: &SimResult) -> Result<Vec<Vec<f32>>> {
        Ok((0..comm.size())
            .map(|r| sim.payloads[r].get_cloned(&0).unwrap_or_default())
            .collect())
    }
}

/// MPI_Barrier rooted at rank 0 (fan-in/fan-out). Carries no data; the
/// decoded vectors are empty.
pub struct Barrier;

impl OpSpec for Barrier {
    fn op_kind(&self) -> OpKind {
        OpKind::Barrier
    }

    fn encode_init(&self, comm: &Communicator) -> Result<Vec<Payload>> {
        Ok(vec![Payload::empty(); comm.size()])
    }

    fn encode_ghost(&self, comm: &Communicator) -> Result<Vec<GhostPayload>> {
        Ok(vec![GhostPayload::empty(); comm.size()])
    }

    fn decode(&self, comm: &Communicator, _sim: &SimResult) -> Result<Vec<Vec<f32>>> {
        Ok(vec![Vec::new(); comm.size()])
    }
}

/// MPI_Gather: rank `r`'s segment `contributions[r]` ends at `root`.
/// Decoded `data` = the per-rank segments as assembled at the root
/// (rank order).
pub struct Gather<'a> {
    pub root: Rank,
    pub contributions: &'a [Vec<f32>],
}

impl OpSpec for Gather<'_> {
    fn op_kind(&self) -> OpKind {
        OpKind::Gather
    }

    fn root(&self) -> Rank {
        self.root
    }

    fn encode_init(&self, comm: &Communicator) -> Result<Vec<Payload>> {
        if self.contributions.len() != comm.size() {
            return Err(Error::Comm(format!(
                "gather: {} contributions for {} ranks",
                self.contributions.len(),
                comm.size()
            )));
        }
        let init: Vec<Payload> = self
            .contributions
            .iter()
            .enumerate()
            .map(|(r, c)| Payload::single(r, c.clone()))
            .collect();
        Ok(init)
    }

    fn decode(&self, comm: &Communicator, sim: &SimResult) -> Result<Vec<Vec<f32>>> {
        let root_payload = &sim.payloads[self.root];
        if root_payload.len() != comm.size() {
            return Err(Error::Verify(format!(
                "gather root holds {} segments, expected {}",
                root_payload.len(),
                comm.size()
            )));
        }
        Ok((0..comm.size())
            .map(|r| root_payload.get_cloned(&r).expect("validated above"))
            .collect())
    }
}

/// MPI_Scatter: `segments[r]` travels from `root` to rank `r`.
/// Decoded `data[r]` = the segment received at rank `r`.
pub struct Scatter<'a> {
    pub root: Rank,
    pub segments: &'a [Vec<f32>],
}

impl OpSpec for Scatter<'_> {
    fn op_kind(&self) -> OpKind {
        OpKind::Scatter
    }

    fn root(&self) -> Rank {
        self.root
    }

    fn encode_init(&self, comm: &Communicator) -> Result<Vec<Payload>> {
        if self.segments.len() != comm.size() {
            return Err(Error::Comm(format!(
                "scatter: {} segments for {} ranks",
                self.segments.len(),
                comm.size()
            )));
        }
        let mut root_payload = Payload::empty();
        for (r, s) in self.segments.iter().enumerate() {
            root_payload.union(Payload::single(r, s.clone())).map_err(Error::Sim)?;
        }
        let mut init = vec![Payload::empty(); comm.size()];
        init[self.root] = root_payload;
        Ok(init)
    }

    fn decode(&self, comm: &Communicator, sim: &SimResult) -> Result<Vec<Vec<f32>>> {
        Ok((0..comm.size())
            .map(|r| sim.payloads[r].get_cloned(&r).unwrap_or_default())
            .collect())
    }
}

/// All-reduce under an [`AlgoPolicy`]: every rank ends with the full
/// reduction. The policy picks the payload convention: uniform
/// reduce+bcast moves one key-0 vector, every chunked policy (rs+ag,
/// hybrid) moves per-destination chunk maps — both decode to the same
/// per-rank reduced vector, bitwise.
pub struct Allreduce<'a> {
    pub root: Rank,
    pub op: ReduceOp,
    pub policy: AlgoPolicy,
    pub contributions: &'a [Vec<f32>],
}

impl OpSpec for Allreduce<'_> {
    fn op_kind(&self) -> OpKind {
        OpKind::Allreduce(self.op, self.policy)
    }

    fn root(&self) -> Rank {
        self.root
    }

    fn encode_init(&self, comm: &Communicator) -> Result<Vec<Payload>> {
        check_contribs(comm, self.contributions)?;
        if !self.policy.is_chunked() {
            let init: Vec<Payload> = self
                .contributions
                .iter()
                .map(|c| Payload::single(0, c.clone()))
                .collect();
            return Ok(init);
        }
        let n = comm.size();
        let len = self.contributions[0].len();
        let ranges = chunk_ranges(len, n);
        let init: Vec<Payload> = self
            .contributions
            .iter()
            .map(|c| {
                let mut pl = Payload::empty();
                for (q, &(lo, hi)) in ranges.iter().enumerate() {
                    pl.union(Payload::single(q, c[lo..hi].to_vec()))
                        .expect("distinct chunk keys");
                }
                pl
            })
            .collect();
        Ok(init)
    }

    fn encode_ghost(&self, comm: &Communicator) -> Result<Vec<GhostPayload>> {
        check_contribs(comm, self.contributions)?;
        let len = self.contributions[0].len();
        Ok(allreduce_ghost_init(comm.size(), len, self.policy))
    }

    fn decode(&self, comm: &Communicator, sim: &SimResult) -> Result<Vec<Vec<f32>>> {
        let n = comm.size();
        if !self.policy.is_chunked() {
            return Ok((0..n)
                .map(|r| sim.payloads[r].get_cloned(&0).unwrap_or_default())
                .collect());
        }
        let len = self.contributions[0].len();
        let mut data = Vec::with_capacity(n);
        for r in 0..n {
            let mut flat = Vec::with_capacity(len);
            for q in 0..n {
                let seg = sim.payloads[r].get(&q).ok_or_else(|| {
                    Error::Verify(format!(
                        "allreduce {}: rank {r} missing chunk {q}",
                        self.policy.name()
                    ))
                })?;
                flat.extend_from_slice(seg);
            }
            data.push(flat);
        }
        Ok(data)
    }
}

/// The per-rank ghost register shape of an allreduce under `policy`:
/// one key-0 segment of `elems` (uniform reduce+bcast) or the
/// `{q: chunk_q}` map of every chunked policy — pure integer arithmetic,
/// shared by [`Allreduce::encode_ghost`] and [`AllreduceProbe`].
fn allreduce_ghost_init(n: usize, elems: usize, policy: AlgoPolicy) -> Vec<GhostPayload> {
    if !policy.is_chunked() {
        return vec![GhostPayload::single(0, elems); n];
    }
    let mut pl = GhostPayload::empty();
    for (q, &(lo, hi)) in chunk_ranges(elems, n).iter().enumerate() {
        pl.push_segment(q, hi - lo);
    }
    vec![pl; n]
}

/// Timing-only allreduce request: carries the payload *shape* (element
/// count) instead of data, so a tuner probe neither materializes `n`
/// contribution vectors nor touches payload memory at all — the
/// per-probe currency of `tune_allreduce_boundary`. Only the ghost path
/// is supported: drive it through `CollectiveEngine::simulate_timing`;
/// `encode_init`/`decode` error.
pub struct AllreduceProbe {
    pub root: Rank,
    pub op: ReduceOp,
    pub policy: AlgoPolicy,
    /// Element count of each rank's (virtual) contribution.
    pub elems: usize,
}

impl OpSpec for AllreduceProbe {
    fn op_kind(&self) -> OpKind {
        OpKind::Allreduce(self.op, self.policy)
    }

    fn root(&self) -> Rank {
        self.root
    }

    fn encode_init(&self, _comm: &Communicator) -> Result<Vec<Payload>> {
        Err(Error::Comm(
            "allreduce probe is timing-only: drive it through simulate_timing".into(),
        ))
    }

    fn encode_ghost(&self, comm: &Communicator) -> Result<Vec<GhostPayload>> {
        Ok(allreduce_ghost_init(comm.size(), self.elems, self.policy))
    }

    fn decode(&self, _comm: &Communicator, _sim: &SimResult) -> Result<Vec<Vec<f32>>> {
        Err(Error::Comm(
            "allreduce probe is timing-only: there is no data to decode".into(),
        ))
    }
}

/// Allgather (§6 extension): every rank contributes `contributions[r]`
/// and ends with every segment. Decoded `data[r]` = concatenation in
/// rank order as assembled at rank `r`.
pub struct Allgather<'a> {
    pub contributions: &'a [Vec<f32>],
}

impl OpSpec for Allgather<'_> {
    fn op_kind(&self) -> OpKind {
        OpKind::Allgather
    }

    fn encode_init(&self, comm: &Communicator) -> Result<Vec<Payload>> {
        if self.contributions.len() != comm.size() {
            return Err(Error::Comm(format!(
                "allgather: {} contributions for {} ranks",
                self.contributions.len(),
                comm.size()
            )));
        }
        let init: Vec<Payload> = self
            .contributions
            .iter()
            .enumerate()
            .map(|(r, c)| Payload::single(r, c.clone()))
            .collect();
        Ok(init)
    }

    fn decode(&self, comm: &Communicator, sim: &SimResult) -> Result<Vec<Vec<f32>>> {
        let n = comm.size();
        let mut data = Vec::with_capacity(n);
        for r in 0..n {
            let segs = &sim.payloads[r];
            if segs.len() != n {
                return Err(Error::Verify(format!(
                    "allgather: rank {r} holds {} segments, expected {n}",
                    segs.len()
                )));
            }
            let mut flat = Vec::new();
            for q in 0..n {
                flat.extend_from_slice(segs.get(&q).expect("validated above"));
            }
            data.push(flat);
        }
        Ok(data)
    }
}

/// Reduce-scatter (§6 extension): `contributions[r][q]` is rank `r`'s
/// contribution to destination `q`'s segment; rank `r` receives the
/// elementwise `op` over all ranks' segment `r`.
pub struct ReduceScatter<'a> {
    pub op: ReduceOp,
    pub contributions: &'a [Vec<Vec<f32>>],
}

impl OpSpec for ReduceScatter<'_> {
    fn op_kind(&self) -> OpKind {
        OpKind::ReduceScatter(self.op)
    }

    fn encode_init(&self, comm: &Communicator) -> Result<Vec<Payload>> {
        let n = comm.size();
        if self.contributions.len() != n || self.contributions.iter().any(|c| c.len() != n) {
            return Err(Error::Comm("reduce_scatter: need n x n segment matrix".into()));
        }
        let init: Vec<Payload> = self
            .contributions
            .iter()
            .map(|per_dst| {
                let mut pl = Payload::empty();
                for (q, seg) in per_dst.iter().enumerate() {
                    pl.union(Payload::single(q, seg.clone())).expect("distinct keys");
                }
                pl
            })
            .collect();
        Ok(init)
    }

    fn decode(&self, comm: &Communicator, sim: &SimResult) -> Result<Vec<Vec<f32>>> {
        Ok((0..comm.size())
            .map(|r| sim.payloads[r].get_cloned(&r).unwrap_or_default())
            .collect())
    }
}

/// Personalized all-to-all (§6 extension): `sends[r][q]` travels from
/// rank `r` to rank `q`. Decoded `data[r]` = concatenation of what `r`
/// received, in source order.
pub struct Alltoall<'a> {
    pub sends: &'a [Vec<Vec<f32>>],
}

impl OpSpec for Alltoall<'_> {
    fn op_kind(&self) -> OpKind {
        OpKind::Alltoall
    }

    fn encode_init(&self, comm: &Communicator) -> Result<Vec<Payload>> {
        let n = comm.size();
        if self.sends.len() != n || self.sends.iter().any(|s| s.len() != n) {
            return Err(Error::Comm("alltoall: need n x n segment matrix".into()));
        }
        let init: Vec<Payload> = self
            .sends
            .iter()
            .enumerate()
            .map(|(src, per_dst)| {
                let mut pl = Payload::empty();
                for (dst, seg) in per_dst.iter().enumerate() {
                    pl.union(Payload::single(a2a_key(n, src, dst), seg.clone()))
                        .expect("distinct keys");
                }
                pl
            })
            .collect();
        Ok(init)
    }

    fn decode(&self, comm: &Communicator, sim: &SimResult) -> Result<Vec<Vec<f32>>> {
        let n = comm.size();
        let mut data = Vec::with_capacity(n);
        for dst in 0..n {
            let mut flat = Vec::new();
            for src in 0..n {
                let key = a2a_key(n, src, dst);
                let seg = sim.payloads[dst].get(&key).ok_or_else(|| {
                    Error::Verify(format!("alltoall: segment {src}->{dst} missing"))
                })?;
                flat.extend_from_slice(seg);
            }
            data.push(flat);
        }
        Ok(data)
    }
}

/// Segmented (pipelined) broadcast — van de Geijn (§5/§6). Splits `data`
/// into `n_segments` chunks streamed down the tree; the (clamped) chunk
/// count participates in the plan key, so each segmentation compiles
/// once.
pub struct BcastSegmented<'a> {
    pub root: Rank,
    pub data: &'a [f32],
    pub n_segments: usize,
}

impl BcastSegmented<'_> {
    fn segs(&self) -> usize {
        self.n_segments.clamp(1, self.data.len().max(1))
    }
}

impl OpSpec for BcastSegmented<'_> {
    fn op_kind(&self) -> OpKind {
        OpKind::BcastSegmented
    }

    fn root(&self) -> Rank {
        self.root
    }

    fn segments(&self) -> usize {
        self.segs()
    }

    fn encode_init(&self, comm: &Communicator) -> Result<Vec<Payload>> {
        let mut root_payload = Payload::empty();
        for (i, &(lo, hi)) in chunk_ranges(self.data.len(), self.segs()).iter().enumerate() {
            root_payload
                .union(Payload::single(i, self.data[lo..hi].to_vec()))
                .map_err(Error::Sim)?;
        }
        let mut init = vec![Payload::empty(); comm.size()];
        init[self.root] = root_payload;
        Ok(init)
    }

    fn encode_ghost(&self, comm: &Communicator) -> Result<Vec<GhostPayload>> {
        let mut root_payload = GhostPayload::empty();
        for (i, &(lo, hi)) in chunk_ranges(self.data.len(), self.segs()).iter().enumerate() {
            root_payload.push_segment(i, hi - lo);
        }
        let mut init = vec![GhostPayload::empty(); comm.size()];
        init[self.root] = root_payload;
        Ok(init)
    }

    fn decode(&self, comm: &Communicator, sim: &SimResult) -> Result<Vec<Vec<f32>>> {
        let segs = self.segs();
        Ok((0..comm.size())
            .map(|r| {
                let mut flat = Vec::new();
                for i in 0..segs {
                    if let Some(s) = sim.payloads[r].get(&i) {
                        flat.extend_from_slice(s);
                    }
                }
                flat
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{AllreduceAlgo, ChunkOrder, LevelAlgo, PlanCache, PlanKey, PLAN_BASE_TAG};
    use crate::topology::TopologySpec;
    use crate::tree::{LevelPolicy, Strategy};

    /// The policy sweep shared by the equivalence tests: the three
    /// legacy shapes plus per-level compositions exercising every
    /// [`LevelAlgo`] and the chunked-pipelining knob.
    fn sweep_policies() -> Vec<AlgoPolicy> {
        vec![
            AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
            AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
            AlgoPolicy::hybrid(1),
            AlgoPolicy::uniform_level(LevelAlgo::Halving),
            AlgoPolicy::composition(&[
                LevelAlgo::ReduceBcast,
                LevelAlgo::Halving,
                LevelAlgo::RsAgRing,
            ])
            .unwrap(),
            AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast).with_chunks(4),
            AlgoPolicy::composition(&[LevelAlgo::RsAgRing, LevelAlgo::Halving])
                .unwrap()
                .with_chunks(2)
                .with_chunk_order(ChunkOrder::ShortestFirst),
        ]
    }

    #[test]
    fn chunk_ranges_cover_and_partition() {
        for (len, n) in [(0usize, 4usize), (1, 4), (5, 4), (8, 4), (9, 4), (20, 1)] {
            let rs = chunk_ranges(len, n);
            assert_eq!(rs.len(), n);
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs[n - 1].1, len);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
        }
    }

    #[test]
    fn spec_compile_agrees_with_cached_plan() {
        // OpSpec::compile and the plan cache go through the same total
        // dispatch: the standalone program equals the cached plan's.
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let data = [1.0f32; 8];
        let spec = Bcast { root: 3, data: &data };
        let plan = cache
            .get_or_build(
                &comm,
                PlanKey {
                    comm_epoch: comm.epoch(),
                    strategy: Strategy::Multilevel,
                    policy: LevelPolicy::paper(),
                    root: spec.root(),
                    op: spec.op_kind(),
                    segments: spec.segments(),
                },
            )
            .unwrap();
        let clustering = comm.clustering();
        let standalone = spec.compile(clustering, &plan.tree, PLAN_BASE_TAG).unwrap();
        assert_eq!(standalone.actions, plan.program.actions);
        assert_eq!(spec.bytes_model(), plan.meta.bytes_model);
        // The allreduce policies are where a second build path exists:
        // the cache composes cached phase programs (plan::cache) while
        // OpSpec::compile runs the standalone total compiler — the two
        // must stay action-identical for every policy.
        let contributions: Vec<Vec<f32>> = vec![vec![0.0; 4]; comm.size()];
        for policy in sweep_policies() {
            let spec = Allreduce {
                root: 0,
                op: ReduceOp::Sum,
                policy,
                contributions: &contributions,
            };
            let plan = cache
                .get_or_build(
                    &comm,
                    PlanKey {
                        comm_epoch: comm.epoch(),
                        strategy: Strategy::Multilevel,
                        policy: LevelPolicy::paper(),
                        root: spec.root(),
                        op: spec.op_kind(),
                        segments: spec.segments(),
                    },
                )
                .unwrap();
            let standalone = spec.compile(clustering, &plan.tree, PLAN_BASE_TAG).unwrap();
            assert_eq!(standalone.actions, plan.program.actions, "{}", policy.name());
        }
    }

    #[test]
    fn ghost_overrides_match_the_derived_encoding() {
        // Every hand-written `encode_ghost` must equal the shape of
        // `encode_init` (the default derivation) — the bit-equality of
        // timing runs rests on it.
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let n = comm.size();
        let data: Vec<f32> = (0..37).map(|i| i as f32).collect();
        let contributions: Vec<Vec<f32>> = (0..n).map(|_| data.clone()).collect();
        let shape_of = |init: &[Payload]| -> Vec<GhostPayload> {
            init.iter().map(GhostPayload::of).collect()
        };
        let specs: Vec<Box<dyn OpSpec + '_>> = vec![
            Box::new(Bcast { root: 3, data: &data }),
            Box::new(Reduce { root: 2, op: ReduceOp::Sum, contributions: &contributions }),
            Box::new(Barrier),
            Box::new(BcastSegmented { root: 1, data: &data, n_segments: 5 }),
        ];
        for spec in &specs {
            let full = spec.encode_init(&comm).unwrap();
            assert_eq!(spec.encode_ghost(&comm).unwrap(), shape_of(&full), "{}", spec.name());
        }
        for policy in sweep_policies() {
            let ar = Allreduce {
                root: 0,
                op: ReduceOp::Sum,
                policy,
                contributions: &contributions,
            };
            let full = ar.encode_init(&comm).unwrap();
            let ghost = ar.encode_ghost(&comm).unwrap();
            assert_eq!(ghost, shape_of(&full), "{}", policy.name());
            // The data-free probe builds the identical shape from the
            // element count alone.
            let probe =
                AllreduceProbe { root: 0, op: ReduceOp::Sum, policy, elems: data.len() };
            assert_eq!(probe.encode_ghost(&comm).unwrap(), ghost, "{}", policy.name());
            assert!(probe.encode_init(&comm).is_err(), "probe has no data path");
        }
    }

    #[test]
    fn request_validation_errors() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let bad: Vec<Vec<f32>> = vec![vec![1.0]];
        assert!(Reduce { root: 0, op: ReduceOp::Sum, contributions: &bad }
            .encode_init(&comm)
            .is_err());
        assert!(Gather { root: 0, contributions: &bad }.encode_init(&comm).is_err());
        assert!(Scatter { root: 0, segments: &bad }.encode_init(&comm).is_err());
        assert!(Allgather { contributions: &bad }.encode_init(&comm).is_err());
        let bad2: Vec<Vec<Vec<f32>>> = vec![vec![vec![1.0]]];
        assert!(ReduceScatter { op: ReduceOp::Sum, contributions: &bad2 }
            .encode_init(&comm)
            .is_err());
        assert!(Alltoall { sends: &bad2 }.encode_init(&comm).is_err());
    }
}
