//! Extended collectives — the paper's §6 future work ("we plan to upgrade
//! MPICH-G2's remaining MPI collective operations in a similar manner"):
//! Allgather, Reduce-scatter, and personalized All-to-all, each built
//! multilevel-topology-aware from the same tree machinery, plus the
//! van de Geijn **segmented (pipelined) broadcast** with a PLogP-style
//! empirical segment-size tuner (§6's second plan).
//!
//! All of these compile to the same simulator IR as the core five: the
//! payload's rank-keyed segment map is expressive enough for
//! per-destination routing (`SendPart::Ranks` filters by key).

use crate::error::Result;
use crate::netsim::{Merge, Program, ReduceOp, SendPart};
use crate::topology::Rank;
use crate::tree::Tree;
use crate::util::counters::count_program_compile;

/// Allgather: every rank contributes a segment; every rank ends with all
/// segments. Implemented as gather-up + broadcast-down over the same tree
/// (each boundary crossed once per direction).
/// Initial payloads: rank `r` holds `{r: segment}`.
pub fn allgather(tree: &Tree, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let mut p = Program::new(n);
    // up phase: union-gather toward the root
    for r in tree.preorder() {
        for &c in tree.children(r) {
            p.recv(r, c, tag, Merge::Union);
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag, SendPart::All);
        }
    }
    // down phase: broadcast the assembled map
    for r in tree.preorder() {
        if let Some(parent) = tree.parent(r) {
            p.recv(r, parent, tag + 1, Merge::Replace);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag + 1, SendPart::All);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Reduce-scatter: elementwise reduction of per-rank segment maps up the
/// tree, then each rank receives (only) its own reduced segment on the
/// way down.
/// Initial payloads: rank `r` holds `{q: contribution_r_for_q}` for all q.
pub fn reduce_scatter(tree: &Tree, op: ReduceOp, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let mut p = Program::new(n);
    // up phase: combine full maps
    for r in tree.preorder() {
        for &c in tree.children(r) {
            p.recv(r, c, tag, Merge::Combine(op));
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag, SendPart::All);
        }
    }
    // down phase: route each subtree's segments to it
    for r in tree.preorder() {
        if let Some(parent) = tree.parent(r) {
            p.recv(r, parent, tag + 1, Merge::Replace);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag + 1, SendPart::Ranks(tree.subtree(c)));
        }
    }
    p.validate()?;
    Ok(p)
}

/// Composite key for all-to-all payload segments: `src * n + dst`.
#[inline]
pub fn a2a_key(n: usize, src: Rank, dst: Rank) -> usize {
    src * n + dst
}

/// Personalized all-to-all over a tree: every rank `r` holds segments
/// `{a2a_key(n, r, q): data}` for all destinations `q`. The tree is used
/// in both directions: gather every outgoing segment to the root (each
/// boundary crossed once upward), then scatter by destination (once
/// downward). Compared with the naive direct exchange this trades WAN
/// crossings (2·(sites-1) vs O(n²/sites)) for root concentration —
/// the same trade the paper's broadcast makes.
pub fn alltoall(tree: &Tree, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let mut p = Program::new(n);
    let mut in_subtree: Vec<Vec<bool>> = vec![vec![false; n]; n];
    for r in 0..n {
        if tree.contains(r) {
            for m in tree.subtree(r) {
                in_subtree[r][m] = true;
            }
        }
    }
    // Up phase: node r forwards segments whose destination lies OUTSIDE
    // its subtree; segments routable within the subtree stay (they are
    // delivered on the way down).
    for r in tree.preorder() {
        for &c in tree.children(r) {
            p.recv(r, c, tag, Merge::Union);
        }
        if let Some(parent) = tree.parent(r) {
            let forward: Vec<usize> = (0..n)
                .flat_map(|s| (0..n).map(move |d| (s, d)))
                .filter(|&(_, d)| !in_subtree[r][d])
                .map(|(s, d)| a2a_key(n, s, d))
                .collect();
            p.send(r, parent, tag, SendPart::Ranks(forward));
        }
    }
    // Down phase: node r sends child c exactly the segments c does not
    // already hold — destination inside c's subtree, source outside it —
    // so the Union merge never sees a duplicate key.
    for r in tree.preorder() {
        if let Some(parent) = tree.parent(r) {
            p.recv(r, parent, tag + 1, Merge::Union);
        }
        for &c in tree.children(r) {
            let keys: Vec<usize> = (0..n)
                .flat_map(|s| (0..n).map(move |d| (s, d)))
                .filter(|&(s, d)| in_subtree[c][d] && !in_subtree[c][s])
                .map(|(s, d)| a2a_key(n, s, d))
                .collect();
            p.send(r, c, tag + 1, SendPart::Ranks(keys));
        }
    }
    p.validate()?;
    Ok(p)
}

/// Segmented, pipelined broadcast (van de Geijn; §5/§6): the message is
/// split into `n_segments` chunks keyed `0..n_segments`; a rank forwards
/// chunk `i` to its children before receiving chunk `i+1`, so chunks
/// stream down the tree concurrently. With S segments over a depth-D
/// path the critical path is ~ (D + S - 1) single-segment hops instead
/// of D full-message hops.
/// Initial payloads: root holds `{i: chunk_i}`.
pub fn bcast_segmented(tree: &Tree, n_segments: usize, tag: u64) -> Result<Program> {
    count_program_compile();
    assert!(n_segments >= 1);
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        for i in 0..n_segments {
            if let Some(parent) = tree.parent(r) {
                p.recv(r, parent, tag + i as u64, Merge::Union);
            }
            for &c in tree.children(r) {
                p.send(r, c, tag + i as u64, SendPart::Ranks(vec![i]));
            }
        }
    }
    p.validate()?;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::netsim::{run, NativeCombiner, Payload, SimConfig};
    use crate::topology::{Communicator, TopologySpec};
    use crate::tree::{build_strategy_tree, LevelPolicy, Strategy};

    fn tree_for(comm: &Communicator, root: usize) -> Tree {
        build_strategy_tree(comm, root, Strategy::Multilevel, &LevelPolicy::paper()).unwrap()
    }

    #[test]
    fn allgather_everyone_gets_everything() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let n = comm.size();
        let t = tree_for(&comm, 0);
        let p = allgather(&t, 100).unwrap();
        let init: Vec<Payload> =
            (0..n).map(|r| Payload::single(r, vec![r as f32; 4])).collect();
        let cfg = SimConfig::new(presets::paper_grid());
        let out = run(comm.clustering(), &p, init, &cfg, &NativeCombiner).unwrap();
        for r in 0..n {
            assert_eq!(out.payloads[r].len(), n, "rank {r}");
            for q in 0..n {
                assert_eq!(out.payloads[r].get(&q).unwrap(), vec![q as f32; 4]);
            }
        }
        // one WAN crossing per direction
        assert_eq!(out.wan_messages(), 2);
    }

    #[test]
    fn reduce_scatter_delivers_reduced_own_segment() {
        let comm = Communicator::world(&TopologySpec::uniform(2, 2, 3).unwrap());
        let n = comm.size();
        let t = tree_for(&comm, 0);
        let p = reduce_scatter(&t, ReduceOp::Sum, 200).unwrap();
        // rank r contributes value (r+1) to every destination's segment
        let init: Vec<Payload> = (0..n)
            .map(|r| {
                let mut pl = Payload::empty();
                for q in 0..n {
                    pl.union(Payload::single(q, vec![(r + 1) as f32; 2])).unwrap();
                }
                pl
            })
            .collect();
        let cfg = SimConfig::new(presets::paper_grid());
        let out = run(comm.clustering(), &p, init, &cfg, &NativeCombiner).unwrap();
        let total: f32 = (1..=n).map(|v| v as f32).sum();
        for r in 0..n {
            assert_eq!(out.payloads[r].get(&r).unwrap(), vec![total; 2], "rank {r}");
        }
        // root keeps everything; leaves hold only their own segment
        let leaf = (0..n).find(|&r| t.children(r).is_empty() && r != 0).unwrap();
        assert_eq!(out.payloads[leaf].len(), 1);
    }

    #[test]
    fn alltoall_full_personalized_exchange() {
        let comm = Communicator::world(&TopologySpec::uniform(2, 2, 2).unwrap());
        let n = comm.size();
        let t = tree_for(&comm, 0);
        let p = alltoall(&t, 300).unwrap();
        let init: Vec<Payload> = (0..n)
            .map(|src| {
                let mut pl = Payload::empty();
                for dst in 0..n {
                    pl.union(Payload::single(
                        a2a_key(n, src, dst),
                        vec![(src * 100 + dst) as f32],
                    ))
                    .unwrap();
                }
                pl
            })
            .collect();
        let cfg = SimConfig::new(presets::paper_grid());
        let out = run(comm.clustering(), &p, init, &cfg, &NativeCombiner).unwrap();
        for dst in 0..n {
            for src in 0..n {
                let key = a2a_key(n, src, dst);
                assert_eq!(
                    out.payloads[dst].get(&key).unwrap(),
                    &[(src * 100 + dst) as f32],
                    "src {src} dst {dst}"
                );
            }
        }
    }

    #[test]
    fn alltoall_wan_crossings_bounded_by_tree() {
        // The hierarchical alltoall crosses the WAN once per direction,
        // versus n²-ish for a naive direct exchange.
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let t = tree_for(&comm, 0);
        let n = comm.size();
        let p = alltoall(&t, 300).unwrap();
        let init: Vec<Payload> = (0..n)
            .map(|src| {
                let mut pl = Payload::empty();
                for dst in 0..n {
                    pl.union(Payload::single(a2a_key(n, src, dst), vec![1.0])).unwrap();
                }
                pl
            })
            .collect();
        let cfg = SimConfig::new(presets::paper_grid());
        let out = run(comm.clustering(), &p, init, &cfg, &NativeCombiner).unwrap();
        assert_eq!(out.wan_messages(), 2, "one WAN message per direction");
    }

    #[test]
    fn segmented_bcast_reassembles_and_pipelines() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let n = comm.size();
        let data: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        let t = tree_for(&comm, 0);
        let cfg = SimConfig::new(presets::paper_grid());

        let run_with_segments = |s: usize| {
            let p = bcast_segmented(&t, s, 500).unwrap();
            let chunk = data.len() / s;
            let mut root_payload = Payload::empty();
            for i in 0..s {
                root_payload
                    .union(Payload::single(i, data[i * chunk..(i + 1) * chunk].to_vec()))
                    .unwrap();
            }
            let mut init = vec![Payload::empty(); n];
            init[0] = root_payload;
            run(comm.clustering(), &p, init, &cfg, &NativeCombiner).unwrap()
        };

        let unsegmented = run_with_segments(1);
        let segmented = run_with_segments(8);
        // reassembly at every rank
        for r in 0..n {
            let mut got = Vec::new();
            for i in 0..8 {
                got.extend_from_slice(&segmented.payloads[r].get(&i).unwrap());
            }
            assert_eq!(got, data, "rank {r}");
        }
        // pipelining shortens the critical path on multi-hop trees
        assert!(
            segmented.makespan_us < unsegmented.makespan_us,
            "segmented {} !< unsegmented {}",
            segmented.makespan_us,
            unsegmented.makespan_us
        );
    }

    #[test]
    fn programs_validate_on_all_strategies() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        for s in Strategy::ALL {
            let t = build_strategy_tree(&comm, 3, s, &LevelPolicy::paper()).unwrap();
            allgather(&t, 1).unwrap();
            reduce_scatter(&t, ReduceOp::Max, 10).unwrap();
            alltoall(&t, 20).unwrap();
            bcast_segmented(&t, 4, 40).unwrap();
        }
    }
}
