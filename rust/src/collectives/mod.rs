//! The multilevel topology-aware collective operations (§3, §6) over a
//! simulated grid, under any of the four strategies of Fig. 8.
//!
//! Since the plan-pipeline refactor, every operation goes through three
//! explicit stages (see [`crate::plan`] for the full story):
//!
//! 1. **topology** — `(Communicator, Strategy, LevelPolicy)` describe the
//!    process group and how trees should hug it;
//! 2. **plan** — a [`crate::plan::CollectivePlan`] (built tree, compiled
//!    program, static metadata) is fetched from a memoizing
//!    [`PlanCache`]; repeated calls with the same `(root, op)` reuse it
//!    with **zero** tree builds and **zero** program compiles;
//! 3. **execute** — `netsim::run` simulates the cached program against
//!    this call's payloads.

pub mod extended;
pub mod programs;
pub mod request;
pub mod verify;

pub use request::OpSpec;

use crate::error::{Error, Result};
use crate::model::NetworkParams;
use crate::netsim::{
    run_indexed_scratch_into, run_indexed_scratch_sharded_into, run_timing_indexed_scratch_into,
    run_timing_indexed_scratch_sharded_into, ChannelIndex, Combiner, ExecMode, ExecScratch,
    GhostPayload, NativeCombiner, Payload, Program, ReduceOp, ShardMap, SimConfig, SimResult,
};
use crate::plan::{
    AlgoPolicy, AllreduceAlgo, CollectivePlan, OpKind, PlanCache, PlanKey, Schedule,
    ScheduleBuilder,
};
use crate::topology::{Communicator, Rank};
use crate::tree::{LevelPolicy, Strategy};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Outcome of a data-carrying collective: simulator metrics plus the
/// delivered data.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub sim: SimResult,
    /// Per-rank result (meaning depends on the operation; see each method).
    pub data: Vec<Vec<f32>>,
}

/// Shared memo of fused schedules, keyed by caller-chosen names — the
/// handle a `GridSession` passes to every engine view it hands out so
/// all of them see (and reuse) the same memoized schedules.
pub type ScheduleMemo = Arc<Mutex<HashMap<String, Arc<Schedule>>>>;

/// The shareable state a `GridSession` threads into every engine view it
/// hands out (crate-internal: sessions construct engines through
/// [`CollectiveEngine::from_parts`] so a view really is a handful of
/// `Arc` clones — `CollectiveEngine::new` would allocate a private
/// cache, scratch and memo only to discard them).
pub(crate) struct EngineParts<'a> {
    pub combiner: &'a dyn Combiner,
    /// The same combiner when it is known `Sync` (`None` for plain custom
    /// combiners) — required by sharded full-mode execution.
    pub combiner_sync: Option<&'a (dyn Combiner + Sync)>,
    pub policy: LevelPolicy,
    pub cache: Arc<PlanCache>,
    pub scratch: Arc<ExecScratch>,
    pub schedules: ScheduleMemo,
    pub trace: bool,
    pub exec_mode: ExecMode,
}

/// The **internal execution layer** binding a communicator, a cost
/// model, a combiner and a strategy. Plans (tree + compiled program) are
/// built once per `(root, op, segmentation)` and memoized in a
/// [`PlanCache`]; each call only constructs initial payloads and runs
/// the simulator against the engine's reusable [`ExecScratch`] arena.
///
/// Every operation is a typed [`request`] value driven through one
/// generic path ([`CollectiveEngine::run`]).
///
/// **Application code should hold a [`crate::session::GridSession`]**
/// (the front door: owned topology, pluggable policy provider, shared
/// caches and scratch) and let it hand out engines; the named
/// convenience wrappers below (`bcast`, `reduce`, …) are kept public but
/// `#[doc(hidden)]` for one release — see the README migration table.
///
/// The cache is engine-private by default; use
/// [`CollectiveEngine::with_plan_cache`] to share one across engines
/// (plans are keyed by [`Communicator::epoch`], so a shared cache never
/// leaks plans between communicators).
pub struct CollectiveEngine<'a> {
    comm: &'a Communicator,
    cfg: SimConfig,
    combiner: &'a dyn Combiner,
    /// `combiner` again, when it is known to be `Sync` — the sharded
    /// engine shares it across worker threads. `None` after
    /// [`CollectiveEngine::with_combiner`] (thread-safety unknown), in
    /// which case sharded full-mode runs fall back to the sequential
    /// path; ghost runs never combine and always shard.
    combiner_sync: Option<&'a (dyn Combiner + Sync)>,
    /// Sequential oracle or cluster-sharded threads — results are
    /// bitwise-identical either way (see [`crate::netsim::shard`]).
    exec_mode: ExecMode,
    strategy: Strategy,
    policy: LevelPolicy,
    allreduce_policy: AlgoPolicy,
    cache: Arc<PlanCache>,
    /// Reusable per-mode execution scratch (mailbox/wait/queue/cursor
    /// storage); engine-private by default, shared across a session's
    /// engines via [`CollectiveEngine::with_scratch`].
    scratch: Arc<ExecScratch>,
    /// Memoized fused schedules, keyed by caller-chosen names (e.g. the
    /// Fig. 7 rotation). A schedule depends only on the engine's
    /// topology/strategy/policy — never on payload sizes — so sweeps
    /// assemble it once (see [`CollectiveEngine::memo_schedule`]). The
    /// map sits behind an `Arc` so a session's short-lived engine views
    /// share one memo.
    schedules: ScheduleMemo,
}

impl<'a> CollectiveEngine<'a> {
    pub fn new(comm: &'a Communicator, params: NetworkParams, strategy: Strategy) -> Self {
        static NATIVE: NativeCombiner = NativeCombiner;
        CollectiveEngine {
            comm,
            cfg: SimConfig::new(params),
            combiner: &NATIVE,
            combiner_sync: Some(&NATIVE),
            exec_mode: ExecMode::Sequential,
            strategy,
            policy: LevelPolicy::paper(),
            allreduce_policy: AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
            cache: Arc::new(PlanCache::new()),
            scratch: Arc::new(ExecScratch::new()),
            schedules: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    /// Engine view over pre-shared session state — no private cache,
    /// scratch or memo is allocated just to be replaced. Crate-internal:
    /// the `GridSession` factory is the intended caller.
    pub(crate) fn from_parts(
        comm: &'a Communicator,
        params: NetworkParams,
        strategy: Strategy,
        parts: EngineParts<'a>,
    ) -> Self {
        let mut cfg = SimConfig::new(params);
        cfg.trace = parts.trace;
        CollectiveEngine {
            comm,
            cfg,
            combiner: parts.combiner,
            combiner_sync: parts.combiner_sync,
            exec_mode: parts.exec_mode,
            strategy,
            policy: parts.policy,
            allreduce_policy: AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
            cache: parts.cache,
            scratch: parts.scratch,
            schedules: parts.schedules,
        }
    }

    /// Replace the combiner. Its thread-safety is unknown here, so
    /// sharded full-mode runs fall back to the sequential path; use
    /// [`CollectiveEngine::with_sync_combiner`] for a `Sync` combiner.
    pub fn with_combiner(mut self, combiner: &'a dyn Combiner) -> Self {
        self.combiner = combiner;
        self.combiner_sync = None;
        self
    }

    /// Replace the combiner with one that may be shared across shard
    /// workers ([`ExecMode::Sharded`] full-mode runs use it directly).
    pub fn with_sync_combiner(mut self, combiner: &'a (dyn Combiner + Sync)) -> Self {
        self.combiner = combiner;
        self.combiner_sync = Some(combiner);
        self
    }

    /// Select sequential or cluster-sharded execution. Sharded runs are
    /// bitwise-identical to sequential ones; the knob trades nothing but
    /// wall-clock (see `netsim::shard`).
    pub fn with_exec_mode(mut self, mode: ExecMode) -> Self {
        self.exec_mode = mode;
        self
    }

    /// The engine's execution mode.
    pub fn exec_mode(&self) -> ExecMode {
        self.exec_mode
    }

    pub fn with_policy(mut self, policy: LevelPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_trace(mut self) -> Self {
        self.cfg = self.cfg.with_trace();
        self
    }

    /// Share a plan cache with other engines (e.g. one cache for all
    /// strategies of an experiment sweep, or across training steps).
    pub fn with_plan_cache(mut self, cache: Arc<PlanCache>) -> Self {
        self.cache = cache;
        self
    }

    /// Share an execution scratch arena with other engines — how a
    /// [`crate::session::GridSession`] keeps back-to-back runs through
    /// its short-lived engine views allocation-free.
    pub fn with_scratch(mut self, scratch: Arc<ExecScratch>) -> Self {
        self.scratch = scratch;
        self
    }

    /// Share the fused-schedule memo map with other engines (again, the
    /// session mechanism: every engine view sees the same memoized
    /// rotation schedule).
    pub fn with_schedule_memo(mut self, memo: ScheduleMemo) -> Self {
        self.schedules = memo;
        self
    }

    /// Default composition used by [`CollectiveEngine::allreduce`]
    /// (shorthand for a uniform [`AlgoPolicy`]).
    pub fn with_allreduce_algo(mut self, algo: AllreduceAlgo) -> Self {
        self.allreduce_policy = AlgoPolicy::uniform(algo);
        self
    }

    /// Default per-level allreduce composition policy used by
    /// [`CollectiveEngine::allreduce`] — e.g. [`AlgoPolicy::hybrid`] for
    /// reduce+bcast across the WAN with rs+ag inside the machines.
    pub fn with_allreduce_policy(mut self, policy: AlgoPolicy) -> Self {
        self.allreduce_policy = policy;
        self
    }

    pub fn strategy(&self) -> Strategy {
        self.strategy
    }

    pub fn comm(&self) -> &Communicator {
        self.comm
    }

    /// Cost-model parameters this engine simulates under.
    pub fn params(&self) -> &NetworkParams {
        &self.cfg.params
    }

    /// The engine's plan cache (for stats or sharing).
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    /// The engine's execution scratch arenas (for sharing).
    pub fn scratch(&self) -> &Arc<ExecScratch> {
        &self.scratch
    }

    /// Start a fused multi-collective [`Schedule`] over this engine's
    /// communicator. Append cached plans via [`CollectiveEngine::plan_for`]
    /// + [`ScheduleBuilder::add_plan`] (zero builds / compiles on a warm
    /// cache) and ad-hoc programs via [`ScheduleBuilder::add_program`],
    /// then execute the whole sequence as **one** simulation with
    /// [`CollectiveEngine::run_schedule`].
    pub fn schedule_builder(&self) -> ScheduleBuilder {
        ScheduleBuilder::new(self.comm)
    }

    /// The fused reduce;bcast allreduce as a two-segment schedule with a
    /// per-phase boundary marker — the same message structure the cached
    /// `Allreduce(ReduceBcast)` plan compiles to, but one fused run now
    /// also reports where the reduce phase ends and the bcast begins.
    pub fn allreduce_schedule(&self, root: Rank, op: ReduceOp) -> Result<Schedule> {
        let red = self.plan_for(root, OpKind::Reduce(op), 1)?;
        let bc = self.plan_for(root, OpKind::Bcast, 1)?;
        let mut b = self.schedule_builder();
        b.add_plan("reduce", &red)?;
        b.add_plan("bcast", &bc)?;
        b.build()
    }

    /// Stage-3 entry point for fused schedules: execute the schedule's
    /// program as a single `netsim` run under this engine's cost model
    /// and combiner.
    pub fn run_schedule(&self, schedule: &Schedule, init: Vec<Payload>) -> Result<SimResult> {
        self.check_schedule_epoch(schedule)?;
        self.execute(schedule.program(), schedule.channels(), schedule.shards(), init)
    }

    /// [`CollectiveEngine::run_schedule`], ghost mode: one timing-only
    /// simulation of the whole schedule. Identical timing and accounting
    /// fields, no payload allocation, empty `SimResult::payloads`.
    pub fn run_schedule_timing(
        &self,
        schedule: &Schedule,
        init: Vec<GhostPayload>,
    ) -> Result<SimResult> {
        self.check_schedule_epoch(schedule)?;
        let mut out = SimResult::default();
        self.execute_timing_into(
            schedule.program(),
            schedule.channels(),
            schedule.shards(),
            init,
            &mut out,
        )?;
        Ok(out)
    }

    fn check_schedule_epoch(&self, schedule: &Schedule) -> Result<()> {
        if schedule.comm_epoch() != self.comm.epoch() {
            return Err(Error::Comm(format!(
                "schedule epoch {} does not match communicator epoch {}",
                schedule.comm_epoch(),
                self.comm.epoch()
            )));
        }
        Ok(())
    }

    /// Memoized schedule slot: return the schedule cached under `key`,
    /// building it with `build` (once) on the first call. Assembly of a
    /// fused schedule is payload-independent — clone + rebase +
    /// re-validate of every segment — so sweeps that execute the same
    /// schedule at many payload sizes hoist it here; the
    /// `schedule_builds` stage counter enforces the single build.
    pub fn memo_schedule(
        &self,
        key: &str,
        build: impl FnOnce() -> Result<Schedule>,
    ) -> Result<Arc<Schedule>> {
        if let Some(s) = self.schedules.lock().unwrap().get(key) {
            return Ok(s.clone());
        }
        // Build outside the lock: assembly consults the plan cache.
        let built = Arc::new(build()?);
        let mut map = self.schedules.lock().unwrap();
        Ok(map.entry(key.to_string()).or_insert(built).clone())
    }

    /// Stage-2 entry point: fetch (or build once) the compiled plan for
    /// `(root, op, segments)` under this engine's strategy and policy.
    pub fn plan_for(
        &self,
        root: Rank,
        op: OpKind,
        segments: usize,
    ) -> Result<Arc<CollectivePlan>> {
        if root >= self.comm.size() {
            return Err(Error::Comm(format!(
                "root {root} out of range for {}-rank communicator",
                self.comm.size()
            )));
        }
        self.cache.get_or_build(
            self.comm,
            PlanKey {
                comm_epoch: self.comm.epoch(),
                strategy: self.strategy,
                policy: self.policy.clone(),
                root,
                op,
                segments,
            },
        )
    }

    /// Stage-3 entry point: run a compiled program against this call's
    /// initial payloads, with its precomputed channel index, shard map
    /// and the engine's recycled full-mode scratch arenas.
    fn execute(
        &self,
        prog: &Program,
        channels: &ChannelIndex,
        shards: &ShardMap,
        init: Vec<Payload>,
    ) -> Result<SimResult> {
        let mut out = SimResult::default();
        self.execute_into(prog, channels, shards, init, &mut out)?;
        Ok(out)
    }

    /// [`CollectiveEngine::execute`] into a caller-owned result buffer,
    /// dispatching on [`ExecMode`]. A sharded engine whose combiner is
    /// not known `Sync` falls back to the sequential oracle (results are
    /// identical by contract; only wall-clock differs).
    fn execute_into(
        &self,
        prog: &Program,
        channels: &ChannelIndex,
        shards: &ShardMap,
        init: Vec<Payload>,
        out: &mut SimResult,
    ) -> Result<()> {
        if let ExecMode::Sharded { threads } = self.exec_mode {
            if let Some(combiner) = self.combiner_sync {
                return run_indexed_scratch_sharded_into(
                    self.comm.clustering(),
                    prog,
                    channels,
                    shards,
                    init,
                    &self.cfg,
                    combiner,
                    &self.scratch,
                    threads,
                    out,
                );
            }
        }
        let mut scratch = self.scratch.full();
        run_indexed_scratch_into(
            self.comm.clustering(),
            prog,
            channels,
            init,
            &self.cfg,
            self.combiner,
            &mut scratch,
            out,
        )
    }

    /// Ghost-mode twin of [`CollectiveEngine::execute_into`]. Ghost
    /// combines are data-free, so sharded execution never needs a `Sync`
    /// combiner.
    fn execute_timing_into(
        &self,
        prog: &Program,
        channels: &ChannelIndex,
        shards: &ShardMap,
        init: Vec<GhostPayload>,
        out: &mut SimResult,
    ) -> Result<()> {
        if let ExecMode::Sharded { threads } = self.exec_mode {
            return run_timing_indexed_scratch_sharded_into(
                self.comm.clustering(),
                prog,
                channels,
                shards,
                init,
                &self.cfg,
                &self.scratch,
                threads,
                out,
            );
        }
        let mut scratch = self.scratch.ghost();
        run_timing_indexed_scratch_into(
            self.comm.clustering(),
            prog,
            channels,
            init,
            &self.cfg,
            &mut scratch,
            out,
        )
    }

    /// The generic request path every collective flows through:
    /// encode the request's inputs, fetch (or build once) its plan,
    /// simulate, decode the per-rank results.
    ///
    /// ```
    /// use gridcollect::collectives::{request, CollectiveEngine};
    /// use gridcollect::model::presets;
    /// use gridcollect::topology::{Communicator, TopologySpec};
    /// use gridcollect::tree::Strategy;
    ///
    /// let comm = Communicator::world(&TopologySpec::paper_fig1());
    /// let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    /// let out = e.run(&request::Bcast { root: 0, data: &[1.0, 2.0] }).unwrap();
    /// assert_eq!(out.data[5], vec![1.0, 2.0]);
    /// ```
    pub fn run(&self, request: &dyn OpSpec) -> Result<Outcome> {
        let sim = self.run_sim(request)?;
        let data = request.decode(self.comm, &sim)?;
        Ok(Outcome { sim, data })
    }

    /// [`CollectiveEngine::run`], measurement path: identical simulation,
    /// but skips decoding per-rank owned copies of the delivered data
    /// (which dominates wall-clock for large payloads — see
    /// EXPERIMENTS.md §Perf). Delivered payloads remain inspectable
    /// (shared) in `SimResult::payloads`.
    pub fn run_sim(&self, request: &dyn OpSpec) -> Result<SimResult> {
        // Plan first: `plan_for` validates the root range, which encoders
        // that index by root rely on.
        let plan = self.plan_for(request.root(), request.op_kind(), request.segments())?;
        let init = request.encode_init(self.comm)?;
        self.execute(&plan.program, &plan.channels, &plan.shards, init)
    }

    /// [`CollectiveEngine::run_sim`], ghost mode: the request layer
    /// plans (warm: cache hit) but skips `encode_init` and `decode` —
    /// initial registers are the request's [`OpSpec::encode_ghost`]
    /// shapes and execution is timing-only. Every timing and accounting
    /// field is bit-identical to the full run's; `SimResult::payloads`
    /// is empty.
    ///
    /// ```
    /// use gridcollect::collectives::{request, CollectiveEngine};
    /// use gridcollect::model::presets;
    /// use gridcollect::topology::{Communicator, TopologySpec};
    /// use gridcollect::tree::Strategy;
    ///
    /// let comm = Communicator::world(&TopologySpec::paper_fig1());
    /// let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    /// let full = e.run_sim(&request::Bcast { root: 0, data: &[1.0; 64] }).unwrap();
    /// let ghost = e.simulate_timing(&request::Bcast { root: 0, data: &[1.0; 64] }).unwrap();
    /// assert_eq!(full.makespan_us, ghost.makespan_us);
    /// assert!(ghost.payloads.is_empty());
    /// ```
    pub fn simulate_timing(&self, request: &dyn OpSpec) -> Result<SimResult> {
        let mut out = SimResult::default();
        self.simulate_timing_into(request, &mut out)?;
        Ok(out)
    }

    /// [`CollectiveEngine::simulate_timing`] into a caller-owned
    /// [`SimResult`] — the fully pooled probe: holding one result buffer
    /// across a sweep recycles every output vector, so a warm probe
    /// allocates nothing at all. On error, `out` is left in an
    /// unspecified partially-written state.
    pub fn simulate_timing_into(&self, request: &dyn OpSpec, out: &mut SimResult) -> Result<()> {
        let plan = self.plan_for(request.root(), request.op_kind(), request.segments())?;
        let init = request.encode_ghost(self.comm)?;
        self.execute_timing_into(&plan.program, &plan.channels, &plan.shards, init, out)
    }

    /// A `Send + Sync` ghost-probing view of this engine for the
    /// parallel driver layer (tuner fan-out, sweep points): same
    /// communicator, cost model, strategy, policy and shared plan
    /// cache / scratch, none of the engine's `!Sync` combiner borrows.
    /// Probes through it are bit-identical to
    /// [`CollectiveEngine::simulate_timing_into`] on a sequential
    /// engine. Borrows the communicator at `'a`, so the prober may
    /// outlive a temporary engine view (the `GridSession` pattern).
    pub fn ghost_prober(&self) -> GhostProber<'a> {
        GhostProber {
            comm: self.comm,
            cfg: self.cfg.clone(),
            strategy: self.strategy,
            policy: self.policy.clone(),
            cache: self.cache.clone(),
            scratch: self.scratch.clone(),
        }
    }

    /// MPI_Bcast: `data` flows from `root` to every rank.
    /// `Outcome::data[r]` = the buffer received at rank `r`.
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn bcast(&self, root: Rank, data: &[f32]) -> Result<Outcome> {
        self.run(&request::Bcast { root, data })
    }

    /// MPI_Bcast, measurement path (see [`CollectiveEngine::run_sim`]).
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn bcast_sim(&self, root: Rank, data: &[f32]) -> Result<SimResult> {
        self.run_sim(&request::Bcast { root, data })
    }

    /// MPI_Reduce: elementwise `op` over every rank's contribution, result
    /// at `root`. `Outcome::data[root]` = the reduced vector (non-roots
    /// hold their partials; MPI leaves them undefined).
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn reduce(&self, root: Rank, op: ReduceOp, contributions: &[Vec<f32>]) -> Result<Outcome> {
        self.run(&request::Reduce { root, op, contributions })
    }

    /// MPI_Barrier rooted at rank 0 (fan-in/fan-out).
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn barrier(&self) -> Result<SimResult> {
        self.run_sim(&request::Barrier)
    }

    /// MPI_Gather: rank `r`'s segment `contributions[r]` ends at `root`.
    /// `Outcome::data` = the per-rank segments as assembled at the root
    /// (rank order).
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn gather(&self, root: Rank, contributions: &[Vec<f32>]) -> Result<Outcome> {
        self.run(&request::Gather { root, contributions })
    }

    /// MPI_Scatter: `segments[r]` travels from `root` to rank `r`.
    /// `Outcome::data[r]` = the segment received at rank `r`.
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn scatter(&self, root: Rank, segments: &[Vec<f32>]) -> Result<Outcome> {
        self.run(&request::Scatter { root, segments })
    }

    /// All-reduce: every rank ends with the full reduction. Uses the
    /// engine's default composition policy (uniform reduce+bcast unless
    /// overridden) rooted at rank 0. Used by the data-parallel training
    /// driver.
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn allreduce(&self, op: ReduceOp, contributions: &[Vec<f32>]) -> Result<Outcome> {
        self.allreduce_at(0, op, contributions)
    }

    /// All-reduce with an explicit internal tree root. The result is
    /// root-independent; the root only shapes the message flow (useful
    /// for load-spreading across repeated calls and for testing).
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn allreduce_at(
        &self,
        root: Rank,
        op: ReduceOp,
        contributions: &[Vec<f32>],
    ) -> Result<Outcome> {
        self.allreduce_with_policy(self.allreduce_policy, root, op, contributions)
    }

    /// All-reduce with an explicit uniform composition algorithm. Both
    /// algorithms deliver bitwise-identical results (same tree, same
    /// combine order); see [`AllreduceAlgo`] for the trade-off.
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn allreduce_with(
        &self,
        algo: AllreduceAlgo,
        root: Rank,
        op: ReduceOp,
        contributions: &[Vec<f32>],
    ) -> Result<Outcome> {
        self.allreduce_with_policy(AlgoPolicy::uniform(algo), root, op, contributions)
    }

    /// All-reduce with an explicit per-level composition policy — e.g.
    /// [`AlgoPolicy::hybrid`] pays reduce+bcast's 2 messages per WAN edge
    /// while keeping rs+ag's pipelined delivery inside the machines. All
    /// policies deliver bitwise-identical results.
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn allreduce_with_policy(
        &self,
        policy: AlgoPolicy,
        root: Rank,
        op: ReduceOp,
        contributions: &[Vec<f32>],
    ) -> Result<Outcome> {
        self.run(&request::Allreduce { root, op, policy, contributions })
    }

    /// Allgather (§6 extension): every rank contributes `contributions[r]`
    /// and ends with every segment. `Outcome::data[r]` = concatenation in
    /// rank order as assembled at rank `r`.
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn allgather(&self, contributions: &[Vec<f32>]) -> Result<Outcome> {
        self.run(&request::Allgather { contributions })
    }

    /// Reduce-scatter (§6 extension): `contributions[r][q]` is rank `r`'s
    /// contribution to destination `q`'s segment; rank `r` receives the
    /// elementwise `op` over all ranks' segment `r`.
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn reduce_scatter(
        &self,
        op: ReduceOp,
        contributions: &[Vec<Vec<f32>>],
    ) -> Result<Outcome> {
        self.run(&request::ReduceScatter { op, contributions })
    }

    /// Personalized all-to-all (§6 extension): `sends[r][q]` travels from
    /// rank `r` to rank `q`. `Outcome::data[r]` = concatenation of what
    /// `r` received, in source order.
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn alltoall(&self, sends: &[Vec<Vec<f32>>]) -> Result<Outcome> {
        self.run(&request::Alltoall { sends })
    }

    /// Segmented (pipelined) broadcast — van de Geijn (§5/§6). Splits
    /// `data` into `n_segments` chunks streamed down the tree. The chunk
    /// count participates in the plan key, so each segmentation compiles
    /// once and sweeps (e.g. [`CollectiveEngine::tune_bcast_segments`])
    /// reuse plans across repeats.
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn bcast_segmented(
        &self,
        root: Rank,
        data: &[f32],
        n_segments: usize,
    ) -> Result<Outcome> {
        self.run(&request::BcastSegmented { root, data, n_segments })
    }

    /// Empirical segment-size tuning (Kielmann's PLogP plan, §6): sweep
    /// candidate segment counts and return `(best_n_segments, best_us)`.
    /// An empty candidate set is an error — there is no segmentation to
    /// report, and silently returning `(1, inf)` would poison downstream
    /// comparisons.
    #[doc(hidden)] // migrating: use `GridSession` (see README migration table)
    pub fn tune_bcast_segments(
        &self,
        root: Rank,
        data: &[f32],
        candidates: &[usize],
    ) -> Result<(usize, f64)> {
        if candidates.is_empty() {
            return Err(Error::Comm("tune_bcast_segments: empty candidate set".into()));
        }
        let mut best = (1usize, f64::INFINITY);
        for &s in candidates {
            let out = self.bcast_segmented(root, data, s)?;
            if out.sim.makespan_us < best.1 {
                best = (s, out.sim.makespan_us);
            }
        }
        Ok(best)
    }
}

/// A thread-shareable **ghost-probing view** of an engine, built by
/// [`CollectiveEngine::ghost_prober`]. The engine itself borrows a
/// `&dyn Combiner` that is not necessarily `Sync`, so it cannot cross
/// threads; ghost probes never combine data, so the prober drops the
/// combiner and keeps only the communicator borrow, the cost model and
/// the shared plan cache / scratch pool. The parallel driver layer
/// (`util::par`) hands one prober to every worker.
///
/// Probes run the **sequential** ghost engine: each worker simulates
/// whole probes independently (the fan-out parallelism is across probes,
/// not within one), which keeps every `SimResult` bit-identical to a
/// serial probe on a sequential engine. Warm probes pop a recycled ghost
/// arena from the shared [`ExecScratch`] pool, so a lone caller
/// allocates nothing at all and `k` concurrent workers settle on `k`
/// pooled arenas.
pub struct GhostProber<'a> {
    comm: &'a Communicator,
    cfg: SimConfig,
    strategy: Strategy,
    policy: LevelPolicy,
    cache: Arc<PlanCache>,
    scratch: Arc<ExecScratch>,
}

// The whole point of the prober: it must cross scoped-thread spawns.
const _: fn() = || {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<GhostProber<'static>>();
};

impl<'a> GhostProber<'a> {
    pub fn comm(&self) -> &'a Communicator {
        self.comm
    }

    /// Mirror of [`CollectiveEngine::plan_for`]: identical validation,
    /// identical [`PlanKey`], same shared cache — a probe warms the
    /// cache for the engine and vice versa.
    pub fn plan_for(
        &self,
        root: Rank,
        op: OpKind,
        segments: usize,
    ) -> Result<Arc<CollectivePlan>> {
        if root >= self.comm.size() {
            return Err(Error::Comm(format!(
                "root {root} out of range for {}-rank communicator",
                self.comm.size()
            )));
        }
        self.cache.get_or_build(
            self.comm,
            PlanKey {
                comm_epoch: self.comm.epoch(),
                strategy: self.strategy,
                policy: self.policy.clone(),
                root,
                op,
                segments,
            },
        )
    }

    /// Mirror of [`CollectiveEngine::simulate_timing_into`] on a
    /// sequential engine: plan (warm: cache hit), encode ghost shapes,
    /// run the timing-only simulator into `out`. On error, `out` is left
    /// in an unspecified partially-written state.
    pub fn simulate_timing_into(&self, request: &dyn OpSpec, out: &mut SimResult) -> Result<()> {
        let plan = self.plan_for(request.root(), request.op_kind(), request.segments())?;
        let init = request.encode_ghost(self.comm)?;
        let mut scratch = self.scratch.ghost();
        run_timing_indexed_scratch_into(
            self.comm.clustering(),
            &plan.program,
            &plan.channels,
            init,
            &self.cfg,
            &mut scratch,
            out,
        )
    }

    /// Mirror of [`CollectiveEngine::run_schedule_timing`] on a
    /// sequential engine, into a caller-owned buffer: one timing-only
    /// simulation of a fused schedule's whole program.
    pub fn run_schedule_timing_into(
        &self,
        schedule: &Schedule,
        init: Vec<GhostPayload>,
        out: &mut SimResult,
    ) -> Result<()> {
        if schedule.comm_epoch() != self.comm.epoch() {
            return Err(Error::Comm(format!(
                "schedule epoch {} does not match communicator epoch {}",
                schedule.comm_epoch(),
                self.comm.epoch()
            )));
        }
        let mut scratch = self.scratch.ghost();
        run_timing_indexed_scratch_into(
            self.comm.clustering(),
            schedule.program(),
            schedule.channels(),
            init,
            &self.cfg,
            &mut scratch,
            out,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::topology::TopologySpec;

    fn engine(strategy: Strategy, comm: &Communicator) -> CollectiveEngine<'_> {
        CollectiveEngine::new(comm, presets::paper_grid(), strategy)
    }

    #[test]
    fn bcast_all_strategies_deliver_identically() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        for s in Strategy::ALL {
            let out = engine(s, &comm).bcast(3, &data).unwrap();
            for r in 0..comm.size() {
                assert_eq!(out.data[r], data, "{} rank {r}", s.name());
            }
        }
    }

    #[test]
    fn multilevel_bcast_fewer_wan_messages_and_faster() {
        let spec = TopologySpec::paper_experiment();
        let comm = Communicator::world(&spec);
        let data = vec![1.0f32; 4096];
        let un = engine(Strategy::Unaware, &comm).bcast(0, &data).unwrap();
        let ml = engine(Strategy::Multilevel, &comm).bcast(0, &data).unwrap();
        assert!(ml.sim.wan_messages() < un.sim.wan_messages());
        assert_eq!(ml.sim.wan_messages(), 1);
        assert!(ml.sim.makespan_us < un.sim.makespan_us);
    }

    #[test]
    fn reduce_matches_reference() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let contributions: Vec<Vec<f32>> =
            (0..comm.size()).map(|r| vec![r as f32, 2.0 * r as f32]).collect();
        let expect = verify::ref_reduce(&contributions, ReduceOp::Sum);
        for s in Strategy::ALL {
            let out = engine(s, &comm).reduce(5, ReduceOp::Sum, &contributions).unwrap();
            assert!(
                verify::close(&out.data[5], &expect, 1e-4, 1e-6),
                "{}: {:?} vs {expect:?}",
                s.name(),
                out.data[5]
            );
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let segments: Vec<Vec<f32>> =
            (0..comm.size()).map(|r| vec![r as f32; 3]).collect();
        for s in Strategy::ALL {
            let e = engine(s, &comm);
            let sc = e.scatter(2, &segments).unwrap();
            assert_eq!(sc.data, segments, "{} scatter", s.name());
            let ga = e.gather(2, &segments).unwrap();
            assert_eq!(ga.data, segments, "{} gather", s.name());
        }
    }

    #[test]
    fn barrier_runs_and_counts_messages() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        for s in Strategy::ALL {
            let sim = engine(s, &comm).barrier().unwrap();
            assert_eq!(sim.msgs_by_sep.iter().sum::<u64>(), 2 * (comm.size() as u64 - 1));
        }
    }

    #[test]
    fn allreduce_delivers_total_everywhere() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let contributions: Vec<Vec<f32>> =
            (0..comm.size()).map(|_| vec![1.0f32; 8]).collect();
        let out = engine(Strategy::Multilevel, &comm)
            .allreduce(ReduceOp::Sum, &contributions)
            .unwrap();
        for r in 0..comm.size() {
            assert_eq!(out.data[r], vec![20.0f32; 8], "rank {r}");
        }
    }

    #[test]
    fn allreduce_algos_agree_bitwise_at_every_root() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let contributions: Vec<Vec<f32>> = (0..comm.size())
            .map(|r| (0..37).map(|i| ((r * 7 + i) % 23) as f32).collect())
            .collect();
        let e = engine(Strategy::Multilevel, &comm);
        let reference = e
            .allreduce_with(AllreduceAlgo::ReduceBcast, 0, ReduceOp::Sum, &contributions)
            .unwrap();
        for root in [0, 3, 10, 19] {
            for algo in AllreduceAlgo::ALL {
                let out =
                    e.allreduce_with(algo, root, ReduceOp::Sum, &contributions).unwrap();
                for r in 0..comm.size() {
                    assert_eq!(
                        out.data[r],
                        reference.data[0],
                        "{} root {root} rank {r}",
                        algo.name()
                    );
                }
            }
        }
    }

    #[test]
    fn allreduce_rsag_handles_short_and_empty_vectors() {
        // Fewer elements than ranks => trailing chunks are empty; zero
        // elements => all chunks empty. Both must round-trip.
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let e = engine(Strategy::Multilevel, &comm);
        for len in [0usize, 1, 5, 19, 20, 21] {
            let contributions: Vec<Vec<f32>> =
                (0..comm.size()).map(|r| vec![(r + 1) as f32; len]).collect();
            let expect = if len == 0 {
                Vec::new()
            } else {
                verify::ref_reduce(&contributions, ReduceOp::Sum)
            };
            let out = e
                .allreduce_with(
                    AllreduceAlgo::ReduceScatterAllgather,
                    0,
                    ReduceOp::Sum,
                    &contributions,
                )
                .unwrap();
            for r in 0..comm.size() {
                assert_eq!(out.data[r], expect, "len {len} rank {r}");
            }
        }
    }

    #[test]
    fn fused_allreduce_schedule_matches_plan_composition() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let e = engine(Strategy::Multilevel, &comm);
        let contributions: Vec<Vec<f32>> =
            (0..comm.size()).map(|r| vec![r as f32; 16]).collect();
        let reference = e.allreduce(ReduceOp::Sum, &contributions).unwrap();
        let s = e.allreduce_schedule(0, ReduceOp::Sum).unwrap();
        let init: Vec<Payload> =
            contributions.iter().map(|c| Payload::single(0, c.clone())).collect();
        let sim = e.run_schedule(&s, init).unwrap();
        // Same message structure and timing as the cached-plan composition;
        // boundary markers are free and tags are timing-neutral.
        assert_eq!(sim.msgs_by_sep, reference.sim.msgs_by_sep);
        assert!((sim.makespan_us - reference.sim.makespan_us).abs() < 1e-9);
        let t = s.segment_completions(&sim).unwrap();
        assert_eq!(t.len(), 2, "reduce and bcast phases");
        assert!(t[0] <= t[1]);
        assert!((t[1] - sim.makespan_us).abs() < 1e-9);
        for r in 0..comm.size() {
            assert_eq!(sim.payloads[r].get(&0).unwrap(), reference.data[r].as_slice());
        }
        // Schedules are epoch-pinned like plans.
        let other = Communicator::world(&spec);
        let e2 = engine(Strategy::Multilevel, &other);
        assert!(e2.run_schedule(&s, vec![Payload::empty(); other.size()]).is_err());
    }

    #[test]
    fn warm_calls_hit_the_plan_cache() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let e = engine(Strategy::Multilevel, &comm);
        let data = vec![1.0f32; 16];
        e.bcast(0, &data).unwrap();
        assert_eq!(e.plan_cache().misses(), 1);
        assert_eq!(e.plan_cache().hits(), 0);
        for _ in 0..5 {
            e.bcast(0, &data).unwrap();
        }
        assert_eq!(e.plan_cache().misses(), 1, "one build, five hits");
        assert_eq!(e.plan_cache().hits(), 5);
        // A different root is a different plan.
        e.bcast(1, &data).unwrap();
        assert_eq!(e.plan_cache().misses(), 2);
    }

    #[test]
    fn plan_cache_shared_across_engines() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let shared = Arc::new(PlanCache::new());
        let a = engine(Strategy::Multilevel, &comm).with_plan_cache(shared.clone());
        let b = engine(Strategy::Multilevel, &comm).with_plan_cache(shared.clone());
        let data = vec![2.0f32; 8];
        a.bcast(4, &data).unwrap();
        let out = b.bcast(4, &data).unwrap();
        assert_eq!(out.data[0], data);
        assert_eq!(shared.misses(), 1, "second engine reused the first's plan");
        assert_eq!(shared.hits(), 1);
    }

    #[test]
    fn input_validation() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let e = engine(Strategy::Multilevel, &comm);
        assert!(e.bcast(99, &[1.0]).is_err());
        assert!(e.reduce(0, ReduceOp::Sum, &[vec![1.0]]).is_err()); // wrong count
        let mut ragged: Vec<Vec<f32>> = (0..comm.size()).map(|_| vec![1.0]).collect();
        ragged[3] = vec![1.0, 2.0];
        assert!(e.reduce(0, ReduceOp::Sum, &ragged).is_err());
        assert!(e.gather(0, &[vec![]]).is_err());
        assert!(e.scatter(0, &[vec![]]).is_err());
        assert!(e.allreduce_at(99, ReduceOp::Sum, &vec![vec![1.0]; comm.size()]).is_err());
    }

    #[test]
    fn tags_do_not_collide_across_calls() {
        // Plans are compiled at a fixed base tag; every run gets an
        // isolated mailbox, so reusing tags across calls is safe.
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let e = engine(Strategy::Multilevel, &comm);
        for i in 0..5 {
            let out = e.bcast(i, &[i as f32]).unwrap();
            assert_eq!(out.data[10][0], i as f32);
        }
    }

    #[test]
    fn tune_bcast_segments_rejects_empty_candidates() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let e = engine(Strategy::Multilevel, &comm);
        let data = vec![1.0f32; 64];
        assert!(e.tune_bcast_segments(0, &data, &[]).is_err());
        let (best, us) = e.tune_bcast_segments(0, &data, &[1, 4]).unwrap();
        assert!(best == 1 || best == 4);
        assert!(us.is_finite());
    }

    #[test]
    fn simulate_timing_matches_run_sim() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let e = engine(Strategy::Multilevel, &comm);
        let contributions: Vec<Vec<f32>> =
            (0..comm.size()).map(|r| vec![r as f32; 33]).collect();
        let req = request::Allreduce {
            root: 0,
            op: ReduceOp::Sum,
            policy: AlgoPolicy::hybrid(1),
            contributions: &contributions,
        };
        let full = e.run_sim(&req).unwrap();
        let ghost = e.simulate_timing(&req).unwrap();
        assert_eq!(full.finish_us, ghost.finish_us);
        assert_eq!(full.msgs_by_sep, ghost.msgs_by_sep);
        assert_eq!(full.bytes_by_sep, ghost.bytes_by_sep);
        assert_eq!(full.combines, ghost.combines);
        assert!(ghost.payloads.is_empty());
        // The data-free probe lands on the same cached plan and timing.
        let probe = request::AllreduceProbe {
            root: 0,
            op: ReduceOp::Sum,
            policy: AlgoPolicy::hybrid(1),
            elems: 33,
        };
        let probed = e.simulate_timing(&probe).unwrap();
        assert_eq!(probed.finish_us, full.finish_us);
        assert!(e.run(&probe).is_err(), "probes have no data path");
    }

    #[test]
    fn memo_schedule_builds_once_and_shares() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let e = engine(Strategy::Multilevel, &comm);
        let a = e.memo_schedule("allreduce", || e.allreduce_schedule(0, ReduceOp::Sum)).unwrap();
        let b = e
            .memo_schedule("allreduce", || panic!("memoized schedule must not rebuild"))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b), "one assembly per key per engine");
        // Ghost execution of the memoized schedule times like the full one.
        let n = comm.size();
        let full_init: Vec<Payload> =
            (0..n).map(|r| Payload::single(0, vec![r as f32; 16])).collect();
        let ghost_init: Vec<crate::netsim::GhostPayload> =
            full_init.iter().map(crate::netsim::GhostPayload::of).collect();
        let full = e.run_schedule(&a, full_init).unwrap();
        let ghost = e.run_schedule_timing(&a, ghost_init).unwrap();
        assert_eq!(full.finish_us, ghost.finish_us);
        assert_eq!(full.mark_times_us, ghost.mark_times_us);
    }

    #[test]
    fn hybrid_policy_through_the_engine() {
        let spec = TopologySpec::paper_experiment();
        let comm = Communicator::world(&spec);
        let e = engine(Strategy::Multilevel, &comm);
        let contributions: Vec<Vec<f32>> = (0..comm.size())
            .map(|r| (0..32).map(|i| ((r + i) % 5) as f32).collect())
            .collect();
        let rb = e
            .allreduce_with(AllreduceAlgo::ReduceBcast, 0, ReduceOp::Sum, &contributions)
            .unwrap();
        let rsag = e
            .allreduce_with(
                AllreduceAlgo::ReduceScatterAllgather,
                0,
                ReduceOp::Sum,
                &contributions,
            )
            .unwrap();
        let hybrid = e
            .allreduce_with_policy(AlgoPolicy::hybrid(1), 0, ReduceOp::Sum, &contributions)
            .unwrap();
        assert_eq!(hybrid.data, rb.data, "bitwise-identical results");
        assert_eq!(hybrid.sim.wan_messages(), rb.sim.wan_messages());
        assert!(hybrid.sim.wan_messages() < rsag.sim.wan_messages());
        // Engine default policy is settable to the hybrid.
        let e2 = engine(Strategy::Multilevel, &comm).with_allreduce_policy(AlgoPolicy::hybrid(1));
        let out = e2.allreduce(ReduceOp::Sum, &contributions).unwrap();
        assert_eq!(out.data, rb.data);
    }
}
