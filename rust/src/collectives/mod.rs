//! The five multilevel topology-aware collective operations (MPI_Bcast,
//! MPI_Reduce, MPI_Barrier, MPI_Gather, MPI_Scatter — §3) over a simulated
//! grid, under any of the four strategies of Fig. 8.

pub mod extended;
pub mod programs;
pub mod verify;

use crate::error::{Error, Result};
use crate::model::NetworkParams;
use crate::netsim::{
    run, Combiner, NativeCombiner, Payload, Program, ReduceOp, SimConfig, SimResult,
};
use crate::topology::{Communicator, Rank};
use crate::tree::{build_strategy_tree, LevelPolicy, Strategy, Tree};
use std::cell::Cell;

/// Outcome of a data-carrying collective: simulator metrics plus the
/// delivered data.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub sim: SimResult,
    /// Per-rank result (meaning depends on the operation; see each method).
    pub data: Vec<Vec<f32>>,
}

/// High-level executor binding a communicator, a cost model, a combiner
/// and a strategy. Each call builds the strategy's tree for the requested
/// root (deterministically, as §3.2 prescribes), compiles the program,
/// and runs the simulator with real payloads.
pub struct CollectiveEngine<'a> {
    comm: &'a Communicator,
    cfg: SimConfig,
    combiner: &'a dyn Combiner,
    strategy: Strategy,
    policy: LevelPolicy,
    next_tag: Cell<u64>,
}

impl<'a> CollectiveEngine<'a> {
    pub fn new(comm: &'a Communicator, params: NetworkParams, strategy: Strategy) -> Self {
        static NATIVE: NativeCombiner = NativeCombiner;
        CollectiveEngine {
            comm,
            cfg: SimConfig::new(params),
            combiner: &NATIVE,
            strategy,
            policy: LevelPolicy::paper(),
            next_tag: Cell::new(1),
        }
    }

    pub fn with_combiner(mut self, combiner: &'a dyn Combiner) -> Self {
        self.combiner = combiner;
        self
    }

    pub fn with_policy(mut self, policy: LevelPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_trace(mut self) -> Self {
        self.cfg = self.cfg.with_trace();
        self
    }

    pub fn strategy(&self) -> Strategy {
        self.strategy
    }

    pub fn comm(&self) -> &Communicator {
        self.comm
    }

    fn take_tag(&self, span: u64) -> u64 {
        let t = self.next_tag.get();
        self.next_tag.set(t + span);
        t
    }

    fn tree_for(&self, root: Rank) -> Result<Tree> {
        if root >= self.comm.size() {
            return Err(Error::Comm(format!(
                "root {root} out of range for {}-rank communicator",
                self.comm.size()
            )));
        }
        build_strategy_tree(self.comm, root, self.strategy, &self.policy)
    }

    fn execute(&self, prog: &Program, init: Vec<Payload>) -> Result<SimResult> {
        run(self.comm.clustering(), prog, init, &self.cfg, self.combiner)
    }

    /// MPI_Bcast: `data` flows from `root` to every rank.
    /// `Outcome::data[r]` = the buffer received at rank `r`.
    pub fn bcast(&self, root: Rank, data: &[f32]) -> Result<Outcome> {
        let sim = self.bcast_sim(root, data)?;
        let data = (0..self.comm.size())
            .map(|r| sim.payloads[r].get_cloned(&root).unwrap_or_default())
            .collect();
        Ok(Outcome { sim, data })
    }

    /// MPI_Bcast, measurement path: identical simulation, but skips
    /// materializing per-rank owned copies of the delivered data (which
    /// dominates wall-clock for large payloads — see EXPERIMENTS.md
    /// §Perf). Delivered payloads remain inspectable (shared) in
    /// `SimResult::payloads`.
    pub fn bcast_sim(&self, root: Rank, data: &[f32]) -> Result<SimResult> {
        let tree = self.tree_for(root)?;
        let prog = programs::bcast(&tree, self.take_tag(16))?;
        let mut init = vec![Payload::empty(); self.comm.size()];
        init[root] = Payload::single(root, data.to_vec());
        self.execute(&prog, init)
    }

    /// MPI_Reduce: elementwise `op` over every rank's contribution, result
    /// at `root`. `Outcome::data[root]` = the reduced vector (non-roots
    /// hold their partials; MPI leaves them undefined).
    pub fn reduce(&self, root: Rank, op: ReduceOp, contributions: &[Vec<f32>]) -> Result<Outcome> {
        self.check_contribs(contributions)?;
        let tree = self.tree_for(root)?;
        let prog = programs::reduce(&tree, op, self.take_tag(16))?;
        let init: Vec<Payload> = contributions
            .iter()
            .map(|c| Payload::single(0, c.clone()))
            .collect();
        let sim = self.execute(&prog, init)?;
        let data = (0..self.comm.size())
            .map(|r| sim.payloads[r].get_cloned(&0).unwrap_or_default())
            .collect();
        Ok(Outcome { sim, data })
    }

    /// MPI_Barrier rooted at rank 0 (fan-in/fan-out).
    pub fn barrier(&self) -> Result<SimResult> {
        let tree = self.tree_for(0)?;
        let prog = programs::barrier(&tree, self.take_tag(16))?;
        self.execute(&prog, vec![Payload::empty(); self.comm.size()])
    }

    /// MPI_Gather: rank `r`'s segment `contributions[r]` ends at `root`.
    /// `Outcome::data` = the per-rank segments as assembled at the root
    /// (rank order).
    pub fn gather(&self, root: Rank, contributions: &[Vec<f32>]) -> Result<Outcome> {
        if contributions.len() != self.comm.size() {
            return Err(Error::Comm(format!(
                "gather: {} contributions for {} ranks",
                contributions.len(),
                self.comm.size()
            )));
        }
        let tree = self.tree_for(root)?;
        let prog = programs::gather(&tree, self.take_tag(16))?;
        let init: Vec<Payload> = contributions
            .iter()
            .enumerate()
            .map(|(r, c)| Payload::single(r, c.clone()))
            .collect();
        let sim = self.execute(&prog, init)?;
        let root_payload = &sim.payloads[root];
        if root_payload.len() != self.comm.size() {
            return Err(Error::Verify(format!(
                "gather root holds {} segments, expected {}",
                root_payload.len(),
                self.comm.size()
            )));
        }
        let data = (0..self.comm.size())
            .map(|r| root_payload.get_cloned(&r).expect("validated above"))
            .collect();
        Ok(Outcome { sim, data })
    }

    /// MPI_Scatter: `segments[r]` travels from `root` to rank `r`.
    /// `Outcome::data[r]` = the segment received at rank `r`.
    pub fn scatter(&self, root: Rank, segments: &[Vec<f32>]) -> Result<Outcome> {
        if segments.len() != self.comm.size() {
            return Err(Error::Comm(format!(
                "scatter: {} segments for {} ranks",
                segments.len(),
                self.comm.size()
            )));
        }
        let tree = self.tree_for(root)?;
        let prog = programs::scatter(&tree, self.take_tag(16))?;
        let mut root_payload = Payload::empty();
        for (r, s) in segments.iter().enumerate() {
            root_payload.union(Payload::single(r, s.clone())).map_err(Error::Sim)?;
        }
        let mut init = vec![Payload::empty(); self.comm.size()];
        init[root] = root_payload;
        let sim = self.execute(&prog, init)?;
        let data = (0..self.comm.size())
            .map(|r| sim.payloads[r].get_cloned(&r).unwrap_or_default())
            .collect();
        Ok(Outcome { sim, data })
    }

    /// All-reduce (reduce to rank 0, broadcast back): every rank ends with
    /// the full reduction. Used by the data-parallel training driver.
    pub fn allreduce(&self, op: ReduceOp, contributions: &[Vec<f32>]) -> Result<Outcome> {
        self.check_contribs(contributions)?;
        let tree = self.tree_for(0)?;
        let prog = programs::allreduce(&tree, &tree, op, self.take_tag(32))?;
        let init: Vec<Payload> =
            contributions.iter().map(|c| Payload::single(0, c.clone())).collect();
        let sim = self.execute(&prog, init)?;
        let data = (0..self.comm.size())
            .map(|r| sim.payloads[r].get_cloned(&0).unwrap_or_default())
            .collect();
        Ok(Outcome { sim, data })
    }

    /// Allgather (§6 extension): every rank contributes `contributions[r]`
    /// and ends with every segment. `Outcome::data[r]` = concatenation in
    /// rank order as assembled at rank `r`.
    pub fn allgather(&self, contributions: &[Vec<f32>]) -> Result<Outcome> {
        if contributions.len() != self.comm.size() {
            return Err(Error::Comm(format!(
                "allgather: {} contributions for {} ranks",
                contributions.len(),
                self.comm.size()
            )));
        }
        let tree = self.tree_for(0)?;
        let prog = extended::allgather(&tree, self.take_tag(16))?;
        let init: Vec<Payload> = contributions
            .iter()
            .enumerate()
            .map(|(r, c)| Payload::single(r, c.clone()))
            .collect();
        let sim = self.execute(&prog, init)?;
        let mut data = Vec::with_capacity(self.comm.size());
        for r in 0..self.comm.size() {
            let segs = &sim.payloads[r];
            if segs.len() != self.comm.size() {
                return Err(Error::Verify(format!(
                    "allgather: rank {r} holds {} segments, expected {}",
                    segs.len(),
                    self.comm.size()
                )));
            }
            let mut flat = Vec::new();
            for q in 0..self.comm.size() {
                flat.extend_from_slice(segs.get(&q).expect("validated above"));
            }
            data.push(flat);
        }
        Ok(Outcome { sim, data })
    }

    /// Reduce-scatter (§6 extension): `contributions[r][q]` is rank `r`'s
    /// contribution to destination `q`'s segment; rank `r` receives the
    /// elementwise `op` over all ranks' segment `r`.
    pub fn reduce_scatter(
        &self,
        op: ReduceOp,
        contributions: &[Vec<Vec<f32>>],
    ) -> Result<Outcome> {
        let n = self.comm.size();
        if contributions.len() != n || contributions.iter().any(|c| c.len() != n) {
            return Err(Error::Comm("reduce_scatter: need n x n segment matrix".into()));
        }
        let tree = self.tree_for(0)?;
        let prog = extended::reduce_scatter(&tree, op, self.take_tag(16))?;
        let init: Vec<Payload> = contributions
            .iter()
            .map(|per_dst| {
                let mut pl = Payload::empty();
                for (q, seg) in per_dst.iter().enumerate() {
                    pl.union(Payload::single(q, seg.clone())).expect("distinct keys");
                }
                pl
            })
            .collect();
        let sim = self.execute(&prog, init)?;
        let data = (0..n)
            .map(|r| sim.payloads[r].get_cloned(&r).unwrap_or_default())
            .collect();
        Ok(Outcome { sim, data })
    }

    /// Personalized all-to-all (§6 extension): `sends[r][q]` travels from
    /// rank `r` to rank `q`. `Outcome::data[r]` = concatenation of what
    /// `r` received, in source order.
    pub fn alltoall(&self, sends: &[Vec<Vec<f32>>]) -> Result<Outcome> {
        let n = self.comm.size();
        if sends.len() != n || sends.iter().any(|s| s.len() != n) {
            return Err(Error::Comm("alltoall: need n x n segment matrix".into()));
        }
        let tree = self.tree_for(0)?;
        let prog = extended::alltoall(&tree, self.take_tag(16))?;
        let init: Vec<Payload> = sends
            .iter()
            .enumerate()
            .map(|(src, per_dst)| {
                let mut pl = Payload::empty();
                for (dst, seg) in per_dst.iter().enumerate() {
                    pl.union(Payload::single(extended::a2a_key(n, src, dst), seg.clone()))
                        .expect("distinct keys");
                }
                pl
            })
            .collect();
        let sim = self.execute(&prog, init)?;
        let mut data = Vec::with_capacity(n);
        for dst in 0..n {
            let mut flat = Vec::new();
            for src in 0..n {
                let key = extended::a2a_key(n, src, dst);
                let seg = sim.payloads[dst].get(&key).ok_or_else(|| {
                    Error::Verify(format!("alltoall: segment {src}->{dst} missing"))
                })?;
                flat.extend_from_slice(seg);
            }
            data.push(flat);
        }
        Ok(Outcome { sim, data })
    }

    /// Segmented (pipelined) broadcast — van de Geijn (§5/§6). Splits
    /// `data` into `n_segments` chunks streamed down the tree.
    pub fn bcast_segmented(
        &self,
        root: Rank,
        data: &[f32],
        n_segments: usize,
    ) -> Result<Outcome> {
        let tree = self.tree_for(root)?;
        let segs = n_segments.clamp(1, data.len().max(1));
        let prog = extended::bcast_segmented(&tree, segs, self.take_tag(segs as u64 + 4))?;
        let mut root_payload = Payload::empty();
        let chunk = data.len().div_ceil(segs);
        for i in 0..segs {
            let lo = (i * chunk).min(data.len());
            let hi = ((i + 1) * chunk).min(data.len());
            root_payload
                .union(Payload::single(i, data[lo..hi].to_vec()))
                .map_err(Error::Sim)?;
        }
        let mut init = vec![Payload::empty(); self.comm.size()];
        init[root] = root_payload;
        let sim = self.execute(&prog, init)?;
        let data = (0..self.comm.size())
            .map(|r| {
                let mut flat = Vec::new();
                for i in 0..segs {
                    if let Some(s) = sim.payloads[r].get(&i) {
                        flat.extend_from_slice(s);
                    }
                }
                flat
            })
            .collect();
        Ok(Outcome { sim, data })
    }

    /// Empirical segment-size tuning (Kielmann's PLogP plan, §6): sweep
    /// candidate segment counts and return `(best_n_segments, best_us)`.
    pub fn tune_bcast_segments(
        &self,
        root: Rank,
        data: &[f32],
        candidates: &[usize],
    ) -> Result<(usize, f64)> {
        let mut best = (1usize, f64::INFINITY);
        for &s in candidates {
            let out = self.bcast_segmented(root, data, s)?;
            if out.sim.makespan_us < best.1 {
                best = (s, out.sim.makespan_us);
            }
        }
        Ok(best)
    }

    fn check_contribs(&self, contributions: &[Vec<f32>]) -> Result<()> {
        if contributions.len() != self.comm.size() {
            return Err(Error::Comm(format!(
                "{} contributions for {} ranks",
                contributions.len(),
                self.comm.size()
            )));
        }
        let len = contributions[0].len();
        if contributions.iter().any(|c| c.len() != len) {
            return Err(Error::Comm("ragged contributions".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::topology::TopologySpec;

    fn engine(strategy: Strategy, comm: &Communicator) -> CollectiveEngine<'_> {
        CollectiveEngine::new(comm, presets::paper_grid(), strategy)
    }

    #[test]
    fn bcast_all_strategies_deliver_identically() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        for s in Strategy::ALL {
            let out = engine(s, &comm).bcast(3, &data).unwrap();
            for r in 0..comm.size() {
                assert_eq!(out.data[r], data, "{} rank {r}", s.name());
            }
        }
    }

    #[test]
    fn multilevel_bcast_fewer_wan_messages_and_faster() {
        let spec = TopologySpec::paper_experiment();
        let comm = Communicator::world(&spec);
        let data = vec![1.0f32; 4096];
        let un = engine(Strategy::Unaware, &comm).bcast(0, &data).unwrap();
        let ml = engine(Strategy::Multilevel, &comm).bcast(0, &data).unwrap();
        assert!(ml.sim.wan_messages() < un.sim.wan_messages());
        assert_eq!(ml.sim.wan_messages(), 1);
        assert!(ml.sim.makespan_us < un.sim.makespan_us);
    }

    #[test]
    fn reduce_matches_reference() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let contributions: Vec<Vec<f32>> =
            (0..comm.size()).map(|r| vec![r as f32, 2.0 * r as f32]).collect();
        let expect = verify::ref_reduce(&contributions, ReduceOp::Sum);
        for s in Strategy::ALL {
            let out = engine(s, &comm).reduce(5, ReduceOp::Sum, &contributions).unwrap();
            assert!(
                verify::close(&out.data[5], &expect, 1e-4, 1e-6),
                "{}: {:?} vs {expect:?}",
                s.name(),
                out.data[5]
            );
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let segments: Vec<Vec<f32>> =
            (0..comm.size()).map(|r| vec![r as f32; 3]).collect();
        for s in Strategy::ALL {
            let e = engine(s, &comm);
            let sc = e.scatter(2, &segments).unwrap();
            assert_eq!(sc.data, segments, "{} scatter", s.name());
            let ga = e.gather(2, &segments).unwrap();
            assert_eq!(ga.data, segments, "{} gather", s.name());
        }
    }

    #[test]
    fn barrier_runs_and_counts_messages() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        for s in Strategy::ALL {
            let sim = engine(s, &comm).barrier().unwrap();
            assert_eq!(sim.msgs_by_sep.iter().sum::<u64>(), 2 * (comm.size() as u64 - 1));
        }
    }

    #[test]
    fn allreduce_delivers_total_everywhere() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let contributions: Vec<Vec<f32>> =
            (0..comm.size()).map(|_| vec![1.0f32; 8]).collect();
        let out = engine(Strategy::Multilevel, &comm)
            .allreduce(ReduceOp::Sum, &contributions)
            .unwrap();
        for r in 0..comm.size() {
            assert_eq!(out.data[r], vec![20.0f32; 8], "rank {r}");
        }
    }

    #[test]
    fn input_validation() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let e = engine(Strategy::Multilevel, &comm);
        assert!(e.bcast(99, &[1.0]).is_err());
        assert!(e.reduce(0, ReduceOp::Sum, &[vec![1.0]]).is_err()); // wrong count
        let mut ragged: Vec<Vec<f32>> = (0..comm.size()).map(|_| vec![1.0]).collect();
        ragged[3] = vec![1.0, 2.0];
        assert!(e.reduce(0, ReduceOp::Sum, &ragged).is_err());
        assert!(e.gather(0, &[vec![]]).is_err());
        assert!(e.scatter(0, &[vec![]]).is_err());
    }

    #[test]
    fn tags_do_not_collide_across_calls() {
        let spec = TopologySpec::paper_fig1();
        let comm = Communicator::world(&spec);
        let e = engine(Strategy::Multilevel, &comm);
        for i in 0..5 {
            let out = e.bcast(i, &[i as f32]).unwrap();
            assert_eq!(out.data[10][0], i as f32);
        }
    }
}
