//! Reference (serial) semantics for the collectives, used by tests,
//! property checks, and the examples to verify simulated outcomes.

use crate::netsim::ReduceOp;

/// Serial reduction in ascending-rank order over equal-length vectors.
pub fn ref_reduce(contributions: &[Vec<f32>], op: ReduceOp) -> Vec<f32> {
    assert!(!contributions.is_empty());
    let mut acc = contributions[0].clone();
    for c in &contributions[1..] {
        assert_eq!(c.len(), acc.len(), "ragged contributions");
        for (a, b) in acc.iter_mut().zip(c) {
            *a = op.apply(*a, *b);
        }
    }
    acc
}

/// Gather reference: just the input, cloned (identity on per-rank data).
pub fn ref_gather(contributions: &[Vec<f32>]) -> Vec<Vec<f32>> {
    contributions.to_vec()
}

/// Relative+absolute tolerance comparison for float reductions whose
/// combine order differs from the serial order (tree folds reassociate).
pub fn close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs())
}

/// Tolerance suitable for a tree reduction of `n` values of magnitude
/// `scale`: the reassociation error of f32 sums grows ~ log2(n) ulps.
pub fn sum_tolerance(n: usize, scale: f32) -> f32 {
    let log_n = (n.max(2) as f32).log2();
    scale * log_n * f32::EPSILON * 8.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ref_reduce_all_ops() {
        let xs = vec![vec![1.0, 4.0], vec![2.0, 3.0], vec![3.0, 2.0]];
        assert_eq!(ref_reduce(&xs, ReduceOp::Sum), vec![6.0, 9.0]);
        assert_eq!(ref_reduce(&xs, ReduceOp::Max), vec![3.0, 4.0]);
        assert_eq!(ref_reduce(&xs, ReduceOp::Min), vec![1.0, 2.0]);
        assert_eq!(ref_reduce(&xs, ReduceOp::Prod), vec![6.0, 24.0]);
    }

    #[test]
    fn close_tolerances() {
        assert!(close(&[1.0], &[1.0 + 1e-7], 1e-6, 0.0));
        assert!(!close(&[1.0], &[1.1], 1e-6, 1e-6));
        assert!(!close(&[1.0], &[1.0, 2.0], 1.0, 1.0));
    }

    #[test]
    fn sum_tolerance_grows_slowly() {
        assert!(sum_tolerance(1024, 1.0) < 1e-4);
        assert!(sum_tolerance(2, 1.0) > 0.0);
    }
}
