//! Compilation of the five MPI collective operations into simulator
//! [`Program`]s, given a communication [`Tree`].
//!
//! Every builder is strategy-agnostic: the tree fully determines the
//! messaging. Per-rank action order encodes the MPICH-style dataflow
//! (receive from parent before forwarding; combine children in child
//! order) so that execution is deterministic.

use crate::error::Result;
use crate::netsim::{Merge, Program, ReduceOp, SendPart};
use crate::tree::Tree;

/// Broadcast (MPI_Bcast): root's payload flows down the tree.
/// Initial payloads: root holds the data; everyone else empty.
pub fn bcast(tree: &Tree, tag: u64) -> Result<Program> {
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        if let Some(parent) = tree.parent(r) {
            p.recv(r, parent, tag, Merge::Replace);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag, SendPart::All);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Reduction (MPI_Reduce): partial values combine up the tree; the root
/// finishes with `op` applied across every rank's contribution.
/// Initial payloads: every rank holds its contribution under segment key 0.
pub fn reduce(tree: &Tree, op: ReduceOp, tag: u64) -> Result<Program> {
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        // Combine children in child order (deterministic fp fold).
        for &c in tree.children(r) {
            p.recv(r, c, tag, Merge::Combine(op));
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag, SendPart::All);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Barrier (MPI_Barrier): zero-byte fan-in to the root, then fan-out.
/// No rank's fan-out receive can complete before every rank has entered
/// the fan-in phase.
pub fn barrier(tree: &Tree, tag: u64) -> Result<Program> {
    let n = tree.capacity();
    let tag_up = tag;
    let tag_down = tag + 1;
    let mut p = Program::new(n);
    for r in tree.preorder() {
        for &c in tree.children(r) {
            p.recv(r, c, tag_up, Merge::Discard);
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag_up, SendPart::Empty);
            p.recv(r, parent, tag_down, Merge::Discard);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag_down, SendPart::Empty);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Gather (MPI_Gather): per-rank segments merge (disjoint union) up the
/// tree; the root finishes holding every rank's segment.
/// Initial payloads: rank `r` holds its segment under key `r`.
pub fn gather(tree: &Tree, tag: u64) -> Result<Program> {
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        for &c in tree.children(r) {
            p.recv(r, c, tag, Merge::Union);
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag, SendPart::All);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Scatter (MPI_Scatter): the root starts with every rank's segment; each
/// edge carries exactly the segments of the child's subtree.
/// Initial payloads: root holds all segments under their owners' keys.
pub fn scatter(tree: &Tree, tag: u64) -> Result<Program> {
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        if let Some(parent) = tree.parent(r) {
            p.recv(r, parent, tag, Merge::Replace);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag, SendPart::Ranks(tree.subtree(c)));
        }
    }
    p.validate()?;
    Ok(p)
}

/// All-reduce composition: reduce to the tree root, then broadcast back
/// down (the MPICH-G2 implementation composes exactly these two phases).
pub fn allreduce(reduce_tree: &Tree, bcast_tree: &Tree, op: ReduceOp, tag: u64) -> Result<Program> {
    let mut p = reduce(reduce_tree, op, tag)?;
    p.then(bcast(bcast_tree, tag + 8)?)?;
    p.validate()?;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::netsim::{run, NativeCombiner, Payload, SimConfig};
    use crate::topology::{Clustering, Rank, TopologySpec};
    use crate::tree::shapes::TreeShape;

    fn line4() -> (Tree, Clustering) {
        let ids: Vec<Rank> = (0..4).collect();
        (TreeShape::Chain.build(4, &ids, 0).unwrap(), Clustering::flat(4))
    }

    fn sim(
        tree_clustering: &Clustering,
        prog: &Program,
        init: Vec<Payload>,
    ) -> crate::netsim::SimResult {
        let cfg = SimConfig::new(presets::uniform_lan(tree_clustering.n_levels()));
        run(tree_clustering, prog, init, &cfg, &NativeCombiner).unwrap()
    }

    #[test]
    fn bcast_delivers_to_all() {
        let (t, c) = line4();
        let p = bcast(&t, 100).unwrap();
        let mut init = vec![Payload::empty(); 4];
        init[0] = Payload::single(0, vec![3.5, 4.5]);
        let r = sim(&c, &p, init);
        for rank in 0..4 {
            assert_eq!(r.payloads[rank].get(&0).unwrap(), vec![3.5, 4.5], "rank {rank}");
        }
    }

    #[test]
    fn reduce_sums_at_root() {
        let ids: Vec<Rank> = (0..6).collect();
        let t = TreeShape::Binomial.build(6, &ids, 2).unwrap();
        let c = Clustering::flat(6);
        let p = reduce(&t, ReduceOp::Sum, 100).unwrap();
        let init: Vec<Payload> =
            (0..6).map(|r| Payload::single(0, vec![r as f32, 1.0])).collect();
        let r = sim(&c, &p, init);
        assert_eq!(r.payloads[2].get(&0).unwrap(), vec![15.0, 6.0]);
        assert_eq!(r.combines, 5, "n-1 combines for n ranks");
    }

    #[test]
    fn reduce_max_min_prod() {
        let ids: Vec<Rank> = (0..4).collect();
        let t = TreeShape::Flat.build(4, &ids, 0).unwrap();
        let c = Clustering::flat(4);
        for (op, expect) in [
            (ReduceOp::Max, 4.0f32),
            (ReduceOp::Min, 1.0),
            (ReduceOp::Prod, 24.0),
        ] {
            let p = reduce(&t, op, 7).unwrap();
            let init: Vec<Payload> =
                (0..4).map(|r| Payload::single(0, vec![(r + 1) as f32])).collect();
            let r = sim(&c, &p, init);
            assert_eq!(r.payloads[0].get(&0).unwrap(), vec![expect], "{op:?}");
        }
    }

    #[test]
    fn gather_collects_everything_at_root() {
        let ids: Vec<Rank> = (0..5).collect();
        let t = TreeShape::Binomial.build(5, &ids, 1).unwrap();
        let c = Clustering::flat(5);
        let p = gather(&t, 3).unwrap();
        let init: Vec<Payload> =
            (0..5).map(|r| Payload::single(r, vec![r as f32; r + 1])).collect();
        let r = sim(&c, &p, init);
        let root_payload = &r.payloads[1];
        assert_eq!(root_payload.len(), 5);
        for rank in 0..5 {
            assert_eq!(root_payload.get(&rank).unwrap(), vec![rank as f32; rank + 1]);
        }
    }

    #[test]
    fn scatter_delivers_own_segment() {
        let ids: Vec<Rank> = (0..6).collect();
        let t = TreeShape::Binomial.build(6, &ids, 0).unwrap();
        let c = Clustering::flat(6);
        let p = scatter(&t, 9).unwrap();
        let mut root_payload = Payload::empty();
        for rank in 0..6 {
            root_payload.union(Payload::single(rank, vec![rank as f32 * 10.0])).unwrap();
        }
        let mut init = vec![Payload::empty(); 6];
        init[0] = root_payload;
        let r = sim(&c, &p, init);
        for rank in 1..6 {
            assert_eq!(
                r.payloads[rank].get(&rank).unwrap(),
                vec![rank as f32 * 10.0],
                "rank {rank}"
            );
        }
    }

    #[test]
    fn scatter_sends_only_subtree_bytes() {
        // Chain 0->1->2->3: edge (0,1) carries segments {1,2,3}; edge (2,3)
        // carries only {3}. Total bytes on the wire = 3+2+1 segments.
        let (t, c) = line4();
        let p = scatter(&t, 9).unwrap();
        let mut root_payload = Payload::empty();
        for rank in 0..4 {
            root_payload.union(Payload::single(rank, vec![0.0; 10])).unwrap(); // 40 B each
        }
        let mut init = vec![Payload::empty(); 4];
        init[0] = root_payload;
        let r = sim(&c, &p, init);
        assert_eq!(r.bytes_by_sep.iter().sum::<u64>(), (3 + 2 + 1) * 40);
    }

    #[test]
    fn barrier_blocks_until_all_enter() {
        let ids: Vec<Rank> = (0..8).collect();
        let t = TreeShape::Binomial.build(8, &ids, 0).unwrap();
        let c = Clustering::flat(8);
        let p = barrier(&t, 50).unwrap();
        let r = sim(&c, &p, vec![Payload::empty(); 8]);
        // Every rank finishes after the slowest leaf's fan-in could reach
        // the root: makespan >= 2 * height * min-latency.
        assert!(r.makespan_us > 0.0);
        assert_eq!(r.bytes_by_sep.iter().sum::<u64>(), 0, "barrier moves no payload bytes");
        // fan-in + fan-out over 7 edges each.
        assert_eq!(r.msgs_by_sep.iter().sum::<u64>(), 14);
    }

    #[test]
    fn allreduce_everyone_gets_total() {
        let ids: Vec<Rank> = (0..5).collect();
        let t = TreeShape::Binomial.build(5, &ids, 0).unwrap();
        let c = Clustering::flat(5);
        let p = allreduce(&t, &t, ReduceOp::Sum, 1000).unwrap();
        let init: Vec<Payload> =
            (0..5).map(|r| Payload::single(0, vec![r as f32 + 1.0])).collect();
        let r = sim(&c, &p, init);
        for rank in 0..5 {
            assert_eq!(r.payloads[rank].get(&0).unwrap(), vec![15.0], "rank {rank}");
        }
    }

    #[test]
    fn programs_validate_on_multilevel_trees() {
        let spec = TopologySpec::paper_experiment();
        let c = spec.clustering();
        let t = crate::tree::build_multilevel(&c, 5, &crate::tree::LevelPolicy::paper()).unwrap();
        for prog in [
            bcast(&t, 1).unwrap(),
            reduce(&t, ReduceOp::Sum, 20).unwrap(),
            barrier(&t, 40).unwrap(),
            gather(&t, 60).unwrap(),
            scatter(&t, 80).unwrap(),
        ] {
            prog.validate().unwrap();
        }
    }
}
