//! Compilation of the five MPI collective operations into simulator
//! [`Program`]s, given a communication [`Tree`].
//!
//! Every builder is strategy-agnostic: the tree fully determines the
//! messaging. Per-rank action order encodes the MPICH-style dataflow
//! (receive from parent before forwarding; combine children in child
//! order) so that execution is deterministic.

use crate::error::Result;
use crate::netsim::{Merge, Program, ReduceOp, SendPart};
use crate::plan::{AlgoPolicy, ChunkOrder, LevelAlgo};
use crate::topology::{Clustering, Rank};
use crate::tree::Tree;
use crate::util::counters::count_program_compile;

/// Broadcast (MPI_Bcast): root's payload flows down the tree.
/// Initial payloads: root holds the data; everyone else empty.
pub fn bcast(tree: &Tree, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        if let Some(parent) = tree.parent(r) {
            p.recv(r, parent, tag, Merge::Replace);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag, SendPart::All);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Reduction (MPI_Reduce): partial values combine up the tree; the root
/// finishes with `op` applied across every rank's contribution.
/// Initial payloads: every rank holds its contribution under segment key 0.
pub fn reduce(tree: &Tree, op: ReduceOp, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        // Combine children in child order (deterministic fp fold).
        for &c in tree.children(r) {
            p.recv(r, c, tag, Merge::Combine(op));
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag, SendPart::All);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Barrier (MPI_Barrier): zero-byte fan-in to the root, then fan-out.
/// No rank's fan-out receive can complete before every rank has entered
/// the fan-in phase.
pub fn barrier(tree: &Tree, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let tag_up = tag;
    let tag_down = tag + 1;
    let mut p = Program::new(n);
    for r in tree.preorder() {
        for &c in tree.children(r) {
            p.recv(r, c, tag_up, Merge::Discard);
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag_up, SendPart::Empty);
            p.recv(r, parent, tag_down, Merge::Discard);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag_down, SendPart::Empty);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Gather (MPI_Gather): per-rank segments merge (disjoint union) up the
/// tree; the root finishes holding every rank's segment.
/// Initial payloads: rank `r` holds its segment under key `r`.
pub fn gather(tree: &Tree, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        for &c in tree.children(r) {
            p.recv(r, c, tag, Merge::Union);
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag, SendPart::All);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Scatter (MPI_Scatter): the root starts with every rank's segment; each
/// edge carries exactly the segments of the child's subtree.
/// Initial payloads: root holds all segments under their owners' keys.
pub fn scatter(tree: &Tree, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        if let Some(parent) = tree.parent(r) {
            p.recv(r, parent, tag, Merge::Replace);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag, SendPart::Ranks(tree.subtree(c)));
        }
    }
    p.validate()?;
    Ok(p)
}

// NOTE: `allreduce` below is the one total composition authority: the
// up phase is always the [`reduce`] dataflow and the delivery phase is
// [`allreduce_down`], glued with `Program::rebase_tags`. The plan cache
// builds the same shape from *cached* phase programs (see
// `plan::PlanCache::build`) so warm composition never recompiles.

/// Coalesce a rank set into sorted, disjoint half-open `[lo, hi)` runs.
///
/// Topology-aware subtrees span rank-contiguous clusters, so the result
/// is typically a handful of intervals — the payload-routing currency of
/// [`SendPart::Ranges`], replacing O(n) rank lists (the ROADMAP 10k-rank
/// scale item).
pub fn rank_runs(ranks: &[Rank]) -> Vec<(Rank, Rank)> {
    let mut sorted: Vec<Rank> = ranks.to_vec();
    sorted.sort_unstable();
    let mut runs: Vec<(Rank, Rank)> = Vec::new();
    for r in sorted {
        match runs.last_mut() {
            Some(last) if last.1 == r => last.1 = r + 1,
            _ => runs.push((r, r + 1)),
        }
    }
    runs
}

/// Intervals of `universe` not covered by `sub` — both sorted and
/// disjoint, with every `sub` run lying inside some `universe` run
/// (subtree ⊆ members, the tree invariant). Keeps the interval-addressed
/// complement exactly equal to the member-set complement the rank-list
/// fallback computes, even for trees over a subset of the rank space.
pub fn subtract_runs(universe: &[(Rank, Rank)], sub: &[(Rank, Rank)]) -> Vec<(Rank, Rank)> {
    let mut out = Vec::new();
    let mut si = 0usize;
    for &(ulo, uhi) in universe {
        let mut lo = ulo;
        while si < sub.len() && sub[si].0 < uhi {
            let (slo, shi) = sub[si];
            debug_assert!(slo >= lo && shi <= uhi, "sub runs must lie within the universe");
            if slo > lo {
                out.push((lo, slo));
            }
            lo = shi;
            si += 1;
        }
        if lo < uhi {
            out.push((lo, uhi));
        }
    }
    out
}

/// How split (subtree/complement) delivery messages address their chunk
/// keys.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkParts {
    /// Coalesced half-open key intervals ([`SendPart::Ranges`]) — O(runs)
    /// per edge; the default.
    Intervals,
    /// Explicit rank lists ([`SendPart::Ranks`]) — the legacy O(n)-per-
    /// edge construction, kept as a fallback and as the reference for the
    /// equal-wire-bytes test.
    RankList,
}

/// Subtree-chunks and complement send parts for one split edge, built
/// from a single `tree.subtree(c)` enumeration. Both addressing modes
/// select exactly `members ∖ subtree(c)` for the complement.
fn split_parts(
    tree: &Tree,
    c: Rank,
    members: &[Rank],
    member_runs: &[(Rank, Rank)],
    parts: ChunkParts,
) -> (SendPart, SendPart) {
    let sub = tree.subtree(c);
    match parts {
        ChunkParts::RankList => {
            let inside: std::collections::HashSet<Rank> = sub.iter().copied().collect();
            let comp: Vec<Rank> =
                members.iter().copied().filter(|m| !inside.contains(m)).collect();
            (SendPart::Ranks(sub), SendPart::Ranks(comp))
        }
        ChunkParts::Intervals => {
            let runs = rank_runs(&sub);
            let comp = subtract_runs(member_runs, &runs);
            (SendPart::Ranges(runs), SendPart::Ranges(comp))
        }
    }
}

/// Index of the unfinished least-loaded piece train: the queue entry
/// with the fewest chunk keys sent so far among those with pieces left
/// (ties toward the earliest entry — child order), or `None` when every
/// train is drained.
fn next_least_loaded(queue: &[(Rank, &PieceSet, usize, usize)]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, &(_, set, next, load)) in queue.iter().enumerate() {
        if next >= set.order.len() {
            continue;
        }
        let better = match best {
            Some(b) => load < queue[b].3,
            None => true,
        };
        if better {
            best = Some(i);
        }
    }
    best
}

/// How one tree edge delivers the reduced map in the down phase —
/// derived per edge from the policy's [`LevelAlgo`] at the edge's
/// separation level plus the chunked-pipelining knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EdgeStyle {
    /// One full-map message at `tag` (reduce+bcast structure).
    Full,
    /// Subtree chunks at `tag` + complement at `tag + 1` (rs+ag ring).
    Split,
    /// The whole map in `k >= 2` interval pieces, piece `g` at
    /// `tag + g`, emitted in the policy's chunk order (recursive-halving
    /// / chunked-pipelining structure).
    Pieces(usize),
}

fn edge_style(policy: AlgoPolicy, sep: usize, n_members: usize) -> EdgeStyle {
    let chunks = policy.chunks_at(sep);
    let k = match policy.level_algo_at(sep) {
        LevelAlgo::RsAgRing => return EdgeStyle::Split,
        // Distance halving always splits the map at least in two.
        LevelAlgo::Halving => chunks.max(2),
        _ => chunks,
    };
    let k = k.min(n_members);
    if k > 1 {
        EdgeStyle::Pieces(k)
    } else {
        EdgeStyle::Full
    }
}

/// The interval pieces a [`EdgeStyle::Pieces`] edge carries, shared by
/// every edge of the plan with the same piece count. `parts[g]` is piece
/// `g`'s key intervals; `order` is the per-child emission schedule (FIFO
/// index order, shortest piece first; least-loaded keeps index order per
/// child — its effect is the cross-child interleave in phase (D));
/// `sizes[g]` is piece `g`'s key count (the least-loaded load unit).
struct PieceSet {
    parts: Vec<SendPart>,
    order: Vec<usize>,
    sizes: Vec<usize>,
}

fn piece_set(sorted_members: &[Rank], k: usize, order: ChunkOrder) -> PieceSet {
    let m = sorted_members.len();
    debug_assert!(k >= 2 && k <= m);
    // Ceil-first contiguous partition of the member chunk keys: the
    // first `m % k` pieces carry one extra key.
    let base = m / k;
    let extra = m % k;
    let mut parts = Vec::with_capacity(k);
    let mut sizes = Vec::with_capacity(k);
    let mut start = 0usize;
    for g in 0..k {
        let len = base + usize::from(g < extra);
        parts.push(SendPart::Ranges(rank_runs(&sorted_members[start..start + len])));
        sizes.push(len);
        start += len;
    }
    let mut idx: Vec<usize> = (0..k).collect();
    if order == ChunkOrder::ShortestFirst {
        idx.sort_by_key(|&g| (sizes[g], g));
    }
    PieceSet { parts, order: idx, sizes }
}

/// Delivery (down) phase of the chunked multilevel allreduce, with a
/// per-edge composition switch driven by the policy's per-level
/// vocabulary: full-structure levels carry the whole reduced map in
/// **one** full-map message per edge (the reduce+bcast structure — 2
/// messages per edge across the whole allreduce); [`LevelAlgo::RsAgRing`]
/// levels split delivery into a subtree-chunks message and a complement
/// message (the rs+ag structure — pipelined, 3 messages per edge);
/// [`LevelAlgo::Halving`] levels (and any full-structure level under a
/// `chunks_per_level() > 1` policy) deliver the map in `k` interval
/// pieces per edge, streamed piece-by-piece through interior ranks in
/// the policy's [`ChunkOrder`].
///
/// Composed after the [`reduce`] up phase (see [`allreduce`]); every
/// rank finishes holding every member's reduced chunk regardless of the
/// policy, so results are independent of the composition.
pub fn allreduce_down(
    tree: &Tree,
    clustering: &Clustering,
    policy: AlgoPolicy,
    tag: u64,
) -> Result<Program> {
    allreduce_down_with(tree, clustering, policy, tag, ChunkParts::Intervals)
}

/// [`allreduce_down`] with an explicit chunk-addressing mode (interval
/// default vs rank-list fallback).
pub fn allreduce_down_with(
    tree: &Tree,
    clustering: &Clustering,
    policy: AlgoPolicy,
    tag: u64,
    parts: ChunkParts,
) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let members: Vec<Rank> = tree.preorder();
    let member_runs = rank_runs(&members);
    let mut sorted_members = members.clone();
    sorted_members.sort_unstable();
    let style_of = |a: Rank, b: Rank| edge_style(policy, clustering.sep(a, b), members.len());
    // One piece table per distinct piece count in this plan (at most two:
    // the chunk knob's k and halving's floor of 2).
    let mut piece_sets: Vec<(usize, PieceSet)> = Vec::new();
    for (pe, ce) in tree.edges() {
        if let EdgeStyle::Pieces(k) = style_of(pe, ce) {
            if !piece_sets.iter().any(|(kk, _)| *kk == k) {
                piece_sets.push((k, piece_set(&sorted_members, k, policy.chunk_order())));
            }
        }
    }
    let pieces_for = |k: usize| -> &PieceSet {
        &piece_sets.iter().find(|(kk, _)| *kk == k).expect("piece set precomputed").1
    };
    let mut p = Program::new(n);
    for &r in &members {
        let parent = tree.parent(r);
        let parent_style = parent.map(|q| style_of(q, r));
        // (A) The first parent delivery replaces the partial map kept
        // from the up phase: the whole map (full edges), the subtree
        // chunks (split edges), or the first scheduled piece (piece
        // edges).
        if let Some(q) = parent {
            let first_tag = match parent_style {
                Some(EdgeStyle::Pieces(k)) => tag + pieces_for(k).order[0] as u64,
                _ => tag,
            };
            p.recv(r, q, first_tag, Merge::Replace);
        }
        // After that first delivery, full- and split-delivered ranks
        // (and the root) already hold their whole subtree's chunks;
        // piece-delivered ranks hold one piece only, so their split-
        // subtree forwarding must wait for phase (D).
        let early_ok = !matches!(parent_style, Some(EdgeStyle::Pieces(_)));
        // (B) Subtree chunks flow on to grandchildren before the
        // complement arrives — the rs+ag pipelining, preserved per split
        // edge. The complement part of each split edge is built here too
        // (one subtree enumeration per edge) and sent after the
        // completing recv.
        let mut split_pending: Vec<(Option<SendPart>, SendPart)> = Vec::new();
        for &c in tree.children(r) {
            if style_of(r, c) == EdgeStyle::Split {
                let (sub, comp) = split_parts(tree, c, &members, &member_runs, parts);
                if early_ok {
                    p.send(r, c, tag, sub);
                    split_pending.push((None, comp));
                } else {
                    split_pending.push((Some(sub), comp));
                }
            }
        }
        // (C) Complete the parent delivery. Split parents owe the
        // complement; piece parents stream the remaining pieces, each
        // forwarded to same-granularity children the moment it lands —
        // the chunked-pipelining payoff.
        match parent_style {
            Some(EdgeStyle::Split) => {
                let q = parent.expect("split parent");
                p.recv(r, q, tag + 1, Merge::Union);
            }
            Some(EdgeStyle::Pieces(k)) => {
                let q = parent.expect("piece parent");
                let set = pieces_for(k);
                let matched: Vec<Rank> = tree
                    .children(r)
                    .iter()
                    .copied()
                    .filter(|&c| style_of(r, c) == EdgeStyle::Pieces(k))
                    .collect();
                for (j, &g) in set.order.iter().enumerate() {
                    if j > 0 {
                        p.recv(r, q, tag + g as u64, Merge::Union);
                    }
                    for &c in &matched {
                        p.send(r, c, tag + g as u64, set.parts[g].clone());
                    }
                }
            }
            _ => {}
        }
        // (D) From here `r` holds every member's chunk: single full-map
        // sends for full edges, deferred-subtree + complement sends for
        // split edges, whole piece schedules for piece edges that could
        // not be pipelined in (C). Under [`ChunkOrder::LeastLoaded`] the
        // deferred piece edges are not emitted child-major: the parent
        // interleaves sibling piece trains, always serving the child
        // with the fewest chunk keys sent so far (ties by child order).
        // Per-child piece order stays FIFO, so every channel still
        // carries its pieces in index order and receivers match tags
        // unchanged — delivery is bitwise identical, only the sender's
        // serialization order moves.
        let ll = policy.chunk_order() == ChunkOrder::LeastLoaded;
        let mut ll_queue: Vec<(Rank, &PieceSet, usize, usize)> = Vec::new();
        if ll {
            for &c in tree.children(r) {
                if let EdgeStyle::Pieces(k) = style_of(r, c) {
                    let pipelined =
                        matches!(parent_style, Some(EdgeStyle::Pieces(pk)) if pk == k);
                    if !pipelined {
                        ll_queue.push((c, pieces_for(k), 0, 0));
                    }
                }
            }
        }
        let mut split_pending = split_pending.into_iter();
        for &c in tree.children(r) {
            match style_of(r, c) {
                EdgeStyle::Full => p.send(r, c, tag, SendPart::All),
                EdgeStyle::Split => {
                    let (sub, comp) =
                        split_pending.next().expect("one entry per split child");
                    if let Some(sub) = sub {
                        p.send(r, c, tag, sub);
                    }
                    p.send(r, c, tag + 1, comp);
                }
                EdgeStyle::Pieces(k) => {
                    let pipelined =
                        matches!(parent_style, Some(EdgeStyle::Pieces(pk)) if pk == k);
                    if pipelined {
                        // Streamed in (C).
                    } else if ll {
                        // Drain the whole interleave at the first piece
                        // child's slot; the queue is empty for the rest.
                        while let Some(i) = next_least_loaded(&ll_queue) {
                            let (child, set, next, load) = &mut ll_queue[i];
                            let g = set.order[*next];
                            p.send(r, *child, tag + g as u64, set.parts[g].clone());
                            *load += set.sizes[g];
                            *next += 1;
                        }
                    } else {
                        let set = pieces_for(k);
                        for &g in &set.order {
                            p.send(r, c, tag + g as u64, set.parts[g].clone());
                        }
                    }
                }
            }
        }
    }
    p.validate()?;
    Ok(p)
}

/// All-reduce over one tree under an [`AlgoPolicy`] — the total compiler
/// behind `OpKind::Allreduce`.
///
/// Inputs are the per-destination chunk maps `reduce_scatter` uses: rank
/// `r` starts with `{q: chunk_q(contribution_r)}` for every destination
/// `q`, and ends holding every reduced chunk. Two phases, glued with a
/// tag rebase:
///
/// 1. **up**: full chunk maps combine toward the root in child order —
///    the exact [`reduce`] dataflow, so every policy's result is bitwise
///    identical (same tree, same combine association);
/// 2. **down**: [`allreduce_down`] under the policy's per-level
///    vocabulary — full-map messages on full-structure levels, split
///    subtree/complement messages on ring levels, streamed interval
///    pieces on halving/chunked levels.
///
/// Total bytes per edge are policy-independent (the full vector crosses
/// every edge once per direction either way); the policy only moves the
/// structure trade-off: splitting or chunking pipelines interior
/// forwarding at the price of extra messages per edge — worth it on
/// fast links, waste on high-latency WAN hops. The plain uniform
/// reduce+bcast policy is *not* compiled here but composed from the
/// cached reduce and bcast plans by `plan::PlanCache::build` (identical
/// structure, zero recompiles); this function still accepts it for
/// standalone use.
pub fn allreduce(
    tree: &Tree,
    clustering: &Clustering,
    op: ReduceOp,
    policy: AlgoPolicy,
    tag: u64,
) -> Result<Program> {
    compose_allreduce(tree, clustering, op, policy, tag, ChunkParts::Intervals)
}

/// The one compose sequence both public allreduce compilers share:
/// reduce up-phase, per-level delivery, tag rebase, re-validate.
fn compose_allreduce(
    tree: &Tree,
    clustering: &Clustering,
    op: ReduceOp,
    policy: AlgoPolicy,
    tag: u64,
    parts: ChunkParts,
) -> Result<Program> {
    let mut p = reduce(tree, op, tag)?;
    let down = allreduce_down_with(tree, clustering, policy, tag, parts)?;
    let delta = p.max_tag() + 1;
    p.then(down.rebased(delta))?;
    p.validate()?;
    Ok(p)
}

/// All-reduce via reduce-scatter + allgather over one tree — uniform
/// split delivery on every edge (uniform rs+ag), interval-addressed.
pub fn allreduce_rsag(tree: &Tree, op: ReduceOp, tag: u64) -> Result<Program> {
    allreduce(
        tree,
        &Clustering::flat(tree.capacity()),
        op,
        AlgoPolicy::uniform(crate::plan::AllreduceAlgo::ReduceScatterAllgather),
        tag,
    )
}

/// [`allreduce_rsag`] with the legacy rank-list chunk addressing — the
/// `SendPart::Ranks` fallback kept for comparison; wire bytes are
/// identical to the interval construction (asserted in tests).
pub fn allreduce_rsag_ranklist(tree: &Tree, op: ReduceOp, tag: u64) -> Result<Program> {
    compose_allreduce(
        tree,
        &Clustering::flat(tree.capacity()),
        op,
        AlgoPolicy::uniform(crate::plan::AllreduceAlgo::ReduceScatterAllgather),
        tag,
        ChunkParts::RankList,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::netsim::{run, NativeCombiner, Payload, SimConfig};
    use crate::topology::{Clustering, Rank, TopologySpec};
    use crate::tree::shapes::TreeShape;

    fn line4() -> (Tree, Clustering) {
        let ids: Vec<Rank> = (0..4).collect();
        (TreeShape::Chain.build(4, &ids, 0).unwrap(), Clustering::flat(4))
    }

    fn sim(
        tree_clustering: &Clustering,
        prog: &Program,
        init: Vec<Payload>,
    ) -> crate::netsim::SimResult {
        let cfg = SimConfig::new(presets::uniform_lan(tree_clustering.n_levels()));
        run(tree_clustering, prog, init, &cfg, &NativeCombiner).unwrap()
    }

    #[test]
    fn bcast_delivers_to_all() {
        let (t, c) = line4();
        let p = bcast(&t, 100).unwrap();
        let mut init = vec![Payload::empty(); 4];
        init[0] = Payload::single(0, vec![3.5, 4.5]);
        let r = sim(&c, &p, init);
        for rank in 0..4 {
            assert_eq!(r.payloads[rank].get(&0).unwrap(), vec![3.5, 4.5], "rank {rank}");
        }
    }

    #[test]
    fn reduce_sums_at_root() {
        let ids: Vec<Rank> = (0..6).collect();
        let t = TreeShape::Binomial.build(6, &ids, 2).unwrap();
        let c = Clustering::flat(6);
        let p = reduce(&t, ReduceOp::Sum, 100).unwrap();
        let init: Vec<Payload> =
            (0..6).map(|r| Payload::single(0, vec![r as f32, 1.0])).collect();
        let r = sim(&c, &p, init);
        assert_eq!(r.payloads[2].get(&0).unwrap(), vec![15.0, 6.0]);
        assert_eq!(r.combines, 5, "n-1 combines for n ranks");
    }

    #[test]
    fn reduce_max_min_prod() {
        let ids: Vec<Rank> = (0..4).collect();
        let t = TreeShape::Flat.build(4, &ids, 0).unwrap();
        let c = Clustering::flat(4);
        for (op, expect) in [
            (ReduceOp::Max, 4.0f32),
            (ReduceOp::Min, 1.0),
            (ReduceOp::Prod, 24.0),
        ] {
            let p = reduce(&t, op, 7).unwrap();
            let init: Vec<Payload> =
                (0..4).map(|r| Payload::single(0, vec![(r + 1) as f32])).collect();
            let r = sim(&c, &p, init);
            assert_eq!(r.payloads[0].get(&0).unwrap(), vec![expect], "{op:?}");
        }
    }

    #[test]
    fn gather_collects_everything_at_root() {
        let ids: Vec<Rank> = (0..5).collect();
        let t = TreeShape::Binomial.build(5, &ids, 1).unwrap();
        let c = Clustering::flat(5);
        let p = gather(&t, 3).unwrap();
        let init: Vec<Payload> =
            (0..5).map(|r| Payload::single(r, vec![r as f32; r + 1])).collect();
        let r = sim(&c, &p, init);
        let root_payload = &r.payloads[1];
        assert_eq!(root_payload.len(), 5);
        for rank in 0..5 {
            assert_eq!(root_payload.get(&rank).unwrap(), vec![rank as f32; rank + 1]);
        }
    }

    #[test]
    fn scatter_delivers_own_segment() {
        let ids: Vec<Rank> = (0..6).collect();
        let t = TreeShape::Binomial.build(6, &ids, 0).unwrap();
        let c = Clustering::flat(6);
        let p = scatter(&t, 9).unwrap();
        let mut root_payload = Payload::empty();
        for rank in 0..6 {
            root_payload.union(Payload::single(rank, vec![rank as f32 * 10.0])).unwrap();
        }
        let mut init = vec![Payload::empty(); 6];
        init[0] = root_payload;
        let r = sim(&c, &p, init);
        for rank in 1..6 {
            assert_eq!(
                r.payloads[rank].get(&rank).unwrap(),
                vec![rank as f32 * 10.0],
                "rank {rank}"
            );
        }
    }

    #[test]
    fn scatter_sends_only_subtree_bytes() {
        // Chain 0->1->2->3: edge (0,1) carries segments {1,2,3}; edge (2,3)
        // carries only {3}. Total bytes on the wire = 3+2+1 segments.
        let (t, c) = line4();
        let p = scatter(&t, 9).unwrap();
        let mut root_payload = Payload::empty();
        for rank in 0..4 {
            root_payload.union(Payload::single(rank, vec![0.0; 10])).unwrap(); // 40 B each
        }
        let mut init = vec![Payload::empty(); 4];
        init[0] = root_payload;
        let r = sim(&c, &p, init);
        assert_eq!(r.bytes_by_sep.iter().sum::<u64>(), (3 + 2 + 1) * 40);
    }

    #[test]
    fn barrier_blocks_until_all_enter() {
        let ids: Vec<Rank> = (0..8).collect();
        let t = TreeShape::Binomial.build(8, &ids, 0).unwrap();
        let c = Clustering::flat(8);
        let p = barrier(&t, 50).unwrap();
        let r = sim(&c, &p, vec![Payload::empty(); 8]);
        // Every rank finishes after the slowest leaf's fan-in could reach
        // the root: makespan >= 2 * height * min-latency.
        assert!(r.makespan_us > 0.0);
        assert_eq!(r.bytes_by_sep.iter().sum::<u64>(), 0, "barrier moves no payload bytes");
        // fan-in + fan-out over 7 edges each.
        assert_eq!(r.msgs_by_sep.iter().sum::<u64>(), 14);
    }

    #[test]
    fn allreduce_everyone_gets_total() {
        // The reduce+bcast composition, built the way the plan cache
        // builds it: cached-phase programs concatenated with a tag
        // rebase (see module note — `allreduce` composes the same shape).
        let ids: Vec<Rank> = (0..5).collect();
        let t = TreeShape::Binomial.build(5, &ids, 0).unwrap();
        let c = Clustering::flat(5);
        let mut p = reduce(&t, ReduceOp::Sum, 1000).unwrap();
        let b = bcast(&t, 1000).unwrap();
        p.then(b.rebased(p.max_tag() + 1)).unwrap();
        p.validate().unwrap();
        let init: Vec<Payload> =
            (0..5).map(|r| Payload::single(0, vec![r as f32 + 1.0])).collect();
        let r = sim(&c, &p, init);
        for rank in 0..5 {
            assert_eq!(r.payloads[rank].get(&0).unwrap(), vec![15.0], "rank {rank}");
        }
    }

    #[test]
    fn allreduce_rsag_delivers_all_chunks_everywhere() {
        // 5 ranks, binomial tree, chunked contributions: rank r holds
        // chunk q of its vector under key q; afterwards every rank must
        // hold every reduced chunk, bitwise equal to the reduce+bcast
        // composition's result.
        let ids: Vec<Rank> = (0..5).collect();
        let t = TreeShape::Binomial.build(5, &ids, 2).unwrap();
        let c = Clustering::flat(5);
        let chunks_of = |r: usize| -> Vec<Vec<f32>> {
            (0..5).map(|q| vec![(r * 5 + q) as f32, 1.0]).collect()
        };
        let init: Vec<Payload> = (0..5)
            .map(|r| {
                let mut pl = Payload::empty();
                for (q, seg) in chunks_of(r).into_iter().enumerate() {
                    pl.union(Payload::single(q, seg)).unwrap();
                }
                pl
            })
            .collect();
        let p = allreduce_rsag(&t, ReduceOp::Sum, 300).unwrap();
        let r = sim(&c, &p, init);
        for rank in 0..5 {
            for q in 0..5 {
                let expect: Vec<f32> =
                    vec![(0..5).map(|src| (src * 5 + q) as f32).sum(), 5.0];
                assert_eq!(r.payloads[rank].get(&q).unwrap(), expect, "rank {rank} chunk {q}");
            }
        }
    }

    #[test]
    fn rank_runs_coalesce() {
        assert_eq!(rank_runs(&[3, 1, 2, 7, 8]), vec![(1, 4), (7, 9)]);
        assert_eq!(rank_runs(&[5]), vec![(5, 6)]);
        assert_eq!(rank_runs(&[]), Vec::<(Rank, Rank)>::new());
    }

    #[test]
    fn subtract_runs_is_the_member_set_difference() {
        // Contiguous universe: plain interval complement.
        assert_eq!(
            subtract_runs(&[(0, 10)], &[(1, 4), (7, 9)]),
            vec![(0, 1), (4, 7), (9, 10)]
        );
        // Gapped universe (subset tree): holes never enter the complement.
        assert_eq!(subtract_runs(&[(0, 2), (5, 9)], &[(6, 8)]), vec![(0, 2), (5, 6), (8, 9)]);
        assert_eq!(subtract_runs(&[(0, 2), (5, 9)], &[(0, 2)]), vec![(5, 9)]);
        assert_eq!(subtract_runs(&[(0, 3)], &[(0, 3)]), Vec::<(Rank, Rank)>::new());
        assert_eq!(subtract_runs(&[(0, 3)], &[]), vec![(0, 3)]);
    }

    /// Build the chunked (`{q: chunk_q}` per rank) initial payloads the
    /// rs+ag/hybrid compositions operate on.
    fn chunked_init(n: usize) -> Vec<Payload> {
        (0..n)
            .map(|r| {
                let mut pl = Payload::empty();
                for q in 0..n {
                    pl.union(Payload::single(q, vec![(r * n + q) as f32, 1.0])).unwrap();
                }
                pl
            })
            .collect()
    }

    #[test]
    fn rsag_intervals_and_ranklist_identical_on_the_wire() {
        // The interval construction must be a pure representation change:
        // same messages, same bytes per level, same delivered payloads,
        // same virtual time as the legacy rank-list fallback.
        let spec = TopologySpec::paper_fig1();
        let c = spec.clustering();
        let t = crate::tree::build_multilevel(&c, 3, &crate::tree::LevelPolicy::paper()).unwrap();
        let n = c.n_ranks();
        let cfg = SimConfig::new(presets::paper_grid());
        let pi = allreduce_rsag(&t, ReduceOp::Sum, 100).unwrap();
        let pl = allreduce_rsag_ranklist(&t, ReduceOp::Sum, 100).unwrap();
        let ri = run(&c, &pi, chunked_init(n), &cfg, &NativeCombiner).unwrap();
        let rl = run(&c, &pl, chunked_init(n), &cfg, &NativeCombiner).unwrap();
        assert_eq!(ri.bytes_by_sep, rl.bytes_by_sep, "equal wire bytes per level");
        assert_eq!(ri.msgs_by_sep, rl.msgs_by_sep);
        assert_eq!(ri.payloads, rl.payloads, "identical delivery");
        assert!((ri.makespan_us - rl.makespan_us).abs() < 1e-9);
    }

    #[test]
    fn hybrid_down_is_full_map_at_the_wan_and_split_below() {
        let spec = TopologySpec::paper_fig1();
        let c = spec.clustering();
        let t = crate::tree::build_multilevel(&c, 0, &crate::tree::LevelPolicy::paper()).unwrap();
        let n = c.n_ranks();
        let cfg = SimConfig::new(presets::paper_grid());
        let hybrid = allreduce(&t, &c, ReduceOp::Sum, AlgoPolicy::hybrid(1), 50).unwrap();
        let rsag = allreduce_rsag(&t, ReduceOp::Sum, 50).unwrap();
        let rh = run(&c, &hybrid, chunked_init(n), &cfg, &NativeCombiner).unwrap();
        let rr = run(&c, &rsag, chunked_init(n), &cfg, &NativeCombiner).unwrap();
        // Fig. 4 tree: exactly one WAN edge. Hybrid: 1 up + 1 full-map
        // down = 2 WAN messages; uniform rs+ag pays 3.
        assert_eq!(rh.wan_messages(), 2, "reduce+bcast structure at the WAN");
        assert_eq!(rr.wan_messages(), 3, "split structure everywhere");
        // Same total bytes either way (full vector per edge per direction).
        assert_eq!(
            rh.bytes_by_sep.iter().sum::<u64>(),
            rr.bytes_by_sep.iter().sum::<u64>()
        );
        // Identical delivery: every rank holds every reduced chunk.
        assert_eq!(rh.payloads, rr.payloads);
        for r in 0..n {
            assert_eq!(rh.payloads[r].len(), n, "rank {r} holds all chunks");
        }
    }

    #[test]
    fn hybrid_boundary_extremes_match_uniform_structures() {
        let spec = TopologySpec::paper_fig1();
        let c = spec.clustering();
        let t = crate::tree::build_multilevel(&c, 0, &crate::tree::LevelPolicy::paper()).unwrap();
        let n = c.n_ranks();
        let cfg = SimConfig::new(presets::paper_grid());
        let sim_of = |p: &Program| run(&c, p, chunked_init(n), &cfg, &NativeCombiner).unwrap();
        // boundary 0 == uniform rs+ag message structure.
        let h0 = allreduce(&t, &c, ReduceOp::Sum, AlgoPolicy::hybrid(0), 1).unwrap();
        let rsag = allreduce_rsag(&t, ReduceOp::Sum, 1).unwrap();
        assert_eq!(sim_of(&h0).msgs_by_sep, sim_of(&rsag).msgs_by_sep);
        // boundary >= n_levels == uniform reduce+bcast structure: two
        // messages per tree edge.
        let hmax = allreduce(&t, &c, ReduceOp::Sum, AlgoPolicy::hybrid(9), 1).unwrap();
        let sim = sim_of(&hmax);
        assert_eq!(sim.msgs_by_sep.iter().sum::<u64>(), 2 * (n as u64 - 1));
    }

    #[test]
    fn compositions_deliver_identically_to_the_uniform_reference() {
        // Every per-level assignment and every chunking knob is a pure
        // message-structure change: same tree, same combine association,
        // so delivered payloads and total bytes match uniform rs+ag
        // bitwise.
        let spec = TopologySpec::paper_fig1();
        let c = spec.clustering();
        let t = crate::tree::build_multilevel(&c, 0, &crate::tree::LevelPolicy::paper()).unwrap();
        let n = c.n_ranks();
        let cfg = SimConfig::new(presets::paper_grid());
        let reference = {
            let p = allreduce_rsag(&t, ReduceOp::Sum, 9).unwrap();
            run(&c, &p, chunked_init(n), &cfg, &NativeCombiner).unwrap()
        };
        let policies = [
            AlgoPolicy::uniform_level(LevelAlgo::Halving),
            AlgoPolicy::uniform(crate::plan::AllreduceAlgo::ReduceBcast).with_chunks(4),
            AlgoPolicy::uniform(crate::plan::AllreduceAlgo::ReduceBcast)
                .with_chunks(3)
                .with_chunk_order(ChunkOrder::ShortestFirst),
            AlgoPolicy::uniform(crate::plan::AllreduceAlgo::ReduceBcast)
                .with_chunks(4)
                .with_chunk_order(ChunkOrder::LeastLoaded),
            AlgoPolicy::composition(&[
                LevelAlgo::ReduceBcast,
                LevelAlgo::Halving,
                LevelAlgo::RsAgRing,
            ])
            .unwrap(),
            AlgoPolicy::composition(&[LevelAlgo::RsAgRing, LevelAlgo::Halving])
                .unwrap()
                .with_chunks(2),
        ];
        for policy in policies {
            let p = allreduce(&t, &c, ReduceOp::Sum, policy, 9).unwrap();
            let r = run(&c, &p, chunked_init(n), &cfg, &NativeCombiner).unwrap();
            assert_eq!(r.payloads, reference.payloads, "{}", policy.name());
            assert_eq!(
                r.bytes_by_sep.iter().sum::<u64>(),
                reference.bytes_by_sep.iter().sum::<u64>(),
                "{}",
                policy.name()
            );
        }
    }

    #[test]
    fn least_loaded_interleaves_sibling_piece_trains() {
        // Flat tree: root 0 with 4 piece children, chunks=2 (piece key
        // counts 3+2 over 5 members). FIFO emits child-major; LL serves
        // the least-loaded child next, so every child's first piece
        // leaves the root before any second piece does. Per-channel
        // piece order is index order either way.
        let ids: Vec<Rank> = (0..5).collect();
        let t = TreeShape::Flat.build(5, &ids, 0).unwrap();
        let c = Clustering::flat(5);
        let sends = |order: ChunkOrder| -> Vec<(Rank, u64)> {
            let policy = AlgoPolicy::uniform(crate::plan::AllreduceAlgo::ReduceBcast)
                .with_chunks(2)
                .with_chunk_order(order);
            let p = allreduce_down(&t, &c, policy, 10).unwrap();
            p.actions[0]
                .iter()
                .filter_map(|a| match a {
                    crate::netsim::Action::Send { to, tag, .. } => Some((*to, *tag)),
                    _ => None,
                })
                .collect()
        };
        let fifo = sends(ChunkOrder::Fifo);
        let ll = sends(ChunkOrder::LeastLoaded);
        assert_eq!(
            fifo,
            vec![(1, 10), (1, 11), (2, 10), (2, 11), (3, 10), (3, 11), (4, 10), (4, 11)]
        );
        assert_eq!(
            ll,
            vec![(1, 10), (2, 10), (3, 10), (4, 10), (1, 11), (2, 11), (3, 11), (4, 11)]
        );
    }

    #[test]
    fn piece_counts_follow_the_chunk_knob() {
        let spec = TopologySpec::paper_fig1();
        let c = spec.clustering();
        let t = crate::tree::build_multilevel(&c, 0, &crate::tree::LevelPolicy::paper()).unwrap();
        let n = c.n_ranks() as u64;
        let cfg = SimConfig::new(presets::paper_grid());
        let sim_of = |policy: AlgoPolicy| {
            let p = allreduce(&t, &c, ReduceOp::Sum, policy, 1).unwrap();
            run(&c, &p, chunked_init(n as usize), &cfg, &NativeCombiner).unwrap()
        };
        // Uniform halving: 1 up + 2 down pieces per edge.
        let rh = sim_of(AlgoPolicy::uniform_level(LevelAlgo::Halving));
        assert_eq!(rh.msgs_by_sep.iter().sum::<u64>(), 3 * (n - 1));
        // Chunked reduce+bcast: 1 up + k down pieces per edge.
        let r4 =
            sim_of(AlgoPolicy::uniform(crate::plan::AllreduceAlgo::ReduceBcast).with_chunks(4));
        assert_eq!(r4.msgs_by_sep.iter().sum::<u64>(), 5 * (n - 1));
    }

    #[test]
    fn programs_validate_on_multilevel_trees() {
        let spec = TopologySpec::paper_experiment();
        let c = spec.clustering();
        let t = crate::tree::build_multilevel(&c, 5, &crate::tree::LevelPolicy::paper()).unwrap();
        for prog in [
            bcast(&t, 1).unwrap(),
            reduce(&t, ReduceOp::Sum, 20).unwrap(),
            barrier(&t, 40).unwrap(),
            gather(&t, 60).unwrap(),
            scatter(&t, 80).unwrap(),
        ] {
            prog.validate().unwrap();
        }
    }
}
