//! Compilation of the five MPI collective operations into simulator
//! [`Program`]s, given a communication [`Tree`].
//!
//! Every builder is strategy-agnostic: the tree fully determines the
//! messaging. Per-rank action order encodes the MPICH-style dataflow
//! (receive from parent before forwarding; combine children in child
//! order) so that execution is deterministic.

use crate::error::Result;
use crate::netsim::{Merge, Program, ReduceOp, SendPart};
use crate::tree::Tree;
use crate::util::counters::count_program_compile;

/// Broadcast (MPI_Bcast): root's payload flows down the tree.
/// Initial payloads: root holds the data; everyone else empty.
pub fn bcast(tree: &Tree, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        if let Some(parent) = tree.parent(r) {
            p.recv(r, parent, tag, Merge::Replace);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag, SendPart::All);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Reduction (MPI_Reduce): partial values combine up the tree; the root
/// finishes with `op` applied across every rank's contribution.
/// Initial payloads: every rank holds its contribution under segment key 0.
pub fn reduce(tree: &Tree, op: ReduceOp, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        // Combine children in child order (deterministic fp fold).
        for &c in tree.children(r) {
            p.recv(r, c, tag, Merge::Combine(op));
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag, SendPart::All);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Barrier (MPI_Barrier): zero-byte fan-in to the root, then fan-out.
/// No rank's fan-out receive can complete before every rank has entered
/// the fan-in phase.
pub fn barrier(tree: &Tree, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let tag_up = tag;
    let tag_down = tag + 1;
    let mut p = Program::new(n);
    for r in tree.preorder() {
        for &c in tree.children(r) {
            p.recv(r, c, tag_up, Merge::Discard);
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag_up, SendPart::Empty);
            p.recv(r, parent, tag_down, Merge::Discard);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag_down, SendPart::Empty);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Gather (MPI_Gather): per-rank segments merge (disjoint union) up the
/// tree; the root finishes holding every rank's segment.
/// Initial payloads: rank `r` holds its segment under key `r`.
pub fn gather(tree: &Tree, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        for &c in tree.children(r) {
            p.recv(r, c, tag, Merge::Union);
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag, SendPart::All);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Scatter (MPI_Scatter): the root starts with every rank's segment; each
/// edge carries exactly the segments of the child's subtree.
/// Initial payloads: root holds all segments under their owners' keys.
pub fn scatter(tree: &Tree, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let mut p = Program::new(n);
    for r in tree.preorder() {
        if let Some(parent) = tree.parent(r) {
            p.recv(r, parent, tag, Merge::Replace);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag, SendPart::Ranks(tree.subtree(c)));
        }
    }
    p.validate()?;
    Ok(p)
}

// NOTE: there is deliberately no `allreduce` compiler here. The
// reduce+bcast composition is built exactly once, in
// `plan::PlanCache::build`, by concatenating the *cached* reduce and
// bcast plans with `Program::rebase_tags` — a second standalone
// implementation would inevitably drift from it.

/// All-reduce via reduce-scatter + allgather over one tree — the
/// segmented-delivery composition ([`crate::plan::AllreduceAlgo`]).
///
/// Inputs are the same per-destination segment maps `reduce_scatter`
/// uses: rank `r` starts with `{q: chunk_q(contribution_r)}` for every
/// destination `q`, and ends holding every reduced chunk. Three phases
/// over the same tree:
///
/// 1. **up** (`tag`): full segment maps combine toward the root, child
///    order — the same elementwise fold as [`reduce`], so the result is
///    bitwise identical to the reduce+bcast composition;
/// 2. **scatter-down** (`tag+1`): each edge `(p, c)` delivers exactly
///    `subtree(c)`'s reduced chunks (the reduce-scatter half);
/// 3. **complement-down** (`tag+2`): each edge delivers the chunks
///    *outside* `subtree(c)` (the allgather half). No up-phase is needed:
///    after phase 2 every ancestor already holds its descendants' chunks.
///
/// Total bytes per edge equal the reduce+bcast composition's (the full
/// vector must cross every edge once per direction either way), but the
/// down-traffic is split into two messages, so a child can forward its
/// subtree's chunks before the complement arrives — pipelining that
/// shortens deep-tree makespans at the price of n-1 extra (small)
/// messages.
pub fn allreduce_rsag(tree: &Tree, op: ReduceOp, tag: u64) -> Result<Program> {
    count_program_compile();
    let n = tree.capacity();
    let members: Vec<usize> = tree.preorder();
    let mut p = Program::new(n);
    // Phase 1: combine full maps up (identical dataflow to `reduce`).
    for &r in &members {
        for &c in tree.children(r) {
            p.recv(r, c, tag, Merge::Combine(op));
        }
        if let Some(parent) = tree.parent(r) {
            p.send(r, parent, tag, SendPart::All);
        }
    }
    // Phases 2+3 interleaved per rank so subtree chunks can be forwarded
    // to grandchildren before the complement arrives from the parent.
    for &r in &members {
        if let Some(parent) = tree.parent(r) {
            // Replace: drops the partial map kept from phase 1.
            p.recv(r, parent, tag + 1, Merge::Replace);
        }
        for &c in tree.children(r) {
            p.send(r, c, tag + 1, SendPart::Ranks(tree.subtree(c)));
        }
        if let Some(parent) = tree.parent(r) {
            p.recv(r, parent, tag + 2, Merge::Union);
        }
        for &c in tree.children(r) {
            let inside: std::collections::HashSet<usize> =
                tree.subtree(c).into_iter().collect();
            let complement: Vec<usize> =
                members.iter().copied().filter(|m| !inside.contains(m)).collect();
            p.send(r, c, tag + 2, SendPart::Ranks(complement));
        }
    }
    p.validate()?;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::netsim::{run, NativeCombiner, Payload, SimConfig};
    use crate::topology::{Clustering, Rank, TopologySpec};
    use crate::tree::shapes::TreeShape;

    fn line4() -> (Tree, Clustering) {
        let ids: Vec<Rank> = (0..4).collect();
        (TreeShape::Chain.build(4, &ids, 0).unwrap(), Clustering::flat(4))
    }

    fn sim(
        tree_clustering: &Clustering,
        prog: &Program,
        init: Vec<Payload>,
    ) -> crate::netsim::SimResult {
        let cfg = SimConfig::new(presets::uniform_lan(tree_clustering.n_levels()));
        run(tree_clustering, prog, init, &cfg, &NativeCombiner).unwrap()
    }

    #[test]
    fn bcast_delivers_to_all() {
        let (t, c) = line4();
        let p = bcast(&t, 100).unwrap();
        let mut init = vec![Payload::empty(); 4];
        init[0] = Payload::single(0, vec![3.5, 4.5]);
        let r = sim(&c, &p, init);
        for rank in 0..4 {
            assert_eq!(r.payloads[rank].get(&0).unwrap(), vec![3.5, 4.5], "rank {rank}");
        }
    }

    #[test]
    fn reduce_sums_at_root() {
        let ids: Vec<Rank> = (0..6).collect();
        let t = TreeShape::Binomial.build(6, &ids, 2).unwrap();
        let c = Clustering::flat(6);
        let p = reduce(&t, ReduceOp::Sum, 100).unwrap();
        let init: Vec<Payload> =
            (0..6).map(|r| Payload::single(0, vec![r as f32, 1.0])).collect();
        let r = sim(&c, &p, init);
        assert_eq!(r.payloads[2].get(&0).unwrap(), vec![15.0, 6.0]);
        assert_eq!(r.combines, 5, "n-1 combines for n ranks");
    }

    #[test]
    fn reduce_max_min_prod() {
        let ids: Vec<Rank> = (0..4).collect();
        let t = TreeShape::Flat.build(4, &ids, 0).unwrap();
        let c = Clustering::flat(4);
        for (op, expect) in [
            (ReduceOp::Max, 4.0f32),
            (ReduceOp::Min, 1.0),
            (ReduceOp::Prod, 24.0),
        ] {
            let p = reduce(&t, op, 7).unwrap();
            let init: Vec<Payload> =
                (0..4).map(|r| Payload::single(0, vec![(r + 1) as f32])).collect();
            let r = sim(&c, &p, init);
            assert_eq!(r.payloads[0].get(&0).unwrap(), vec![expect], "{op:?}");
        }
    }

    #[test]
    fn gather_collects_everything_at_root() {
        let ids: Vec<Rank> = (0..5).collect();
        let t = TreeShape::Binomial.build(5, &ids, 1).unwrap();
        let c = Clustering::flat(5);
        let p = gather(&t, 3).unwrap();
        let init: Vec<Payload> =
            (0..5).map(|r| Payload::single(r, vec![r as f32; r + 1])).collect();
        let r = sim(&c, &p, init);
        let root_payload = &r.payloads[1];
        assert_eq!(root_payload.len(), 5);
        for rank in 0..5 {
            assert_eq!(root_payload.get(&rank).unwrap(), vec![rank as f32; rank + 1]);
        }
    }

    #[test]
    fn scatter_delivers_own_segment() {
        let ids: Vec<Rank> = (0..6).collect();
        let t = TreeShape::Binomial.build(6, &ids, 0).unwrap();
        let c = Clustering::flat(6);
        let p = scatter(&t, 9).unwrap();
        let mut root_payload = Payload::empty();
        for rank in 0..6 {
            root_payload.union(Payload::single(rank, vec![rank as f32 * 10.0])).unwrap();
        }
        let mut init = vec![Payload::empty(); 6];
        init[0] = root_payload;
        let r = sim(&c, &p, init);
        for rank in 1..6 {
            assert_eq!(
                r.payloads[rank].get(&rank).unwrap(),
                vec![rank as f32 * 10.0],
                "rank {rank}"
            );
        }
    }

    #[test]
    fn scatter_sends_only_subtree_bytes() {
        // Chain 0->1->2->3: edge (0,1) carries segments {1,2,3}; edge (2,3)
        // carries only {3}. Total bytes on the wire = 3+2+1 segments.
        let (t, c) = line4();
        let p = scatter(&t, 9).unwrap();
        let mut root_payload = Payload::empty();
        for rank in 0..4 {
            root_payload.union(Payload::single(rank, vec![0.0; 10])).unwrap(); // 40 B each
        }
        let mut init = vec![Payload::empty(); 4];
        init[0] = root_payload;
        let r = sim(&c, &p, init);
        assert_eq!(r.bytes_by_sep.iter().sum::<u64>(), (3 + 2 + 1) * 40);
    }

    #[test]
    fn barrier_blocks_until_all_enter() {
        let ids: Vec<Rank> = (0..8).collect();
        let t = TreeShape::Binomial.build(8, &ids, 0).unwrap();
        let c = Clustering::flat(8);
        let p = barrier(&t, 50).unwrap();
        let r = sim(&c, &p, vec![Payload::empty(); 8]);
        // Every rank finishes after the slowest leaf's fan-in could reach
        // the root: makespan >= 2 * height * min-latency.
        assert!(r.makespan_us > 0.0);
        assert_eq!(r.bytes_by_sep.iter().sum::<u64>(), 0, "barrier moves no payload bytes");
        // fan-in + fan-out over 7 edges each.
        assert_eq!(r.msgs_by_sep.iter().sum::<u64>(), 14);
    }

    #[test]
    fn allreduce_everyone_gets_total() {
        // The reduce+bcast composition, built the way the plan cache
        // builds it: cached-phase programs concatenated with a tag
        // rebase (no dedicated compiler exists — see module note).
        let ids: Vec<Rank> = (0..5).collect();
        let t = TreeShape::Binomial.build(5, &ids, 0).unwrap();
        let c = Clustering::flat(5);
        let mut p = reduce(&t, ReduceOp::Sum, 1000).unwrap();
        let b = bcast(&t, 1000).unwrap();
        p.then(b.rebased(p.max_tag() + 1)).unwrap();
        p.validate().unwrap();
        let init: Vec<Payload> =
            (0..5).map(|r| Payload::single(0, vec![r as f32 + 1.0])).collect();
        let r = sim(&c, &p, init);
        for rank in 0..5 {
            assert_eq!(r.payloads[rank].get(&0).unwrap(), vec![15.0], "rank {rank}");
        }
    }

    #[test]
    fn allreduce_rsag_delivers_all_chunks_everywhere() {
        // 5 ranks, binomial tree, chunked contributions: rank r holds
        // chunk q of its vector under key q; afterwards every rank must
        // hold every reduced chunk, bitwise equal to the reduce+bcast
        // composition's result.
        let ids: Vec<Rank> = (0..5).collect();
        let t = TreeShape::Binomial.build(5, &ids, 2).unwrap();
        let c = Clustering::flat(5);
        let chunks_of = |r: usize| -> Vec<Vec<f32>> {
            (0..5).map(|q| vec![(r * 5 + q) as f32, 1.0]).collect()
        };
        let init: Vec<Payload> = (0..5)
            .map(|r| {
                let mut pl = Payload::empty();
                for (q, seg) in chunks_of(r).into_iter().enumerate() {
                    pl.union(Payload::single(q, seg)).unwrap();
                }
                pl
            })
            .collect();
        let p = allreduce_rsag(&t, ReduceOp::Sum, 300).unwrap();
        let r = sim(&c, &p, init);
        for rank in 0..5 {
            for q in 0..5 {
                let expect: Vec<f32> =
                    vec![(0..5).map(|src| (src * 5 + q) as f32).sum(), 5.0];
                assert_eq!(r.payloads[rank].get(&q).unwrap(), expect, "rank {rank} chunk {q}");
            }
        }
    }

    #[test]
    fn programs_validate_on_multilevel_trees() {
        let spec = TopologySpec::paper_experiment();
        let c = spec.clustering();
        let t = crate::tree::build_multilevel(&c, 5, &crate::tree::LevelPolicy::paper()).unwrap();
        for prog in [
            bcast(&t, 1).unwrap(),
            reduce(&t, ReduceOp::Sum, 20).unwrap(),
            barrier(&t, 40).unwrap(),
            gather(&t, 60).unwrap(),
            scatter(&t, 80).unwrap(),
        ] {
            prog.validate().unwrap();
        }
    }
}
