//! Streaming and batch statistics used by the benchmark harness, the
//! simulator reports, and the experiment drivers.

/// Welford online accumulator: mean/variance without storing samples.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Half-width of the ~95% confidence interval on the mean
    /// (normal approximation; fine for the n≥20 we use in benches).
    pub fn ci95_half(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            1.96 * self.stddev() / (self.n as f64).sqrt()
        }
    }
}

/// Batch summary with exact percentiles (stores samples).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self { samples: Vec::new(), sorted: true }
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Linear-interpolated percentile, `q` in [0,100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q), "percentile out of range: {q}");
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let n = self.samples.len();
        if n == 1 {
            return self.samples[0];
        }
        let pos = q / 100.0 * (n - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.samples.first().copied().unwrap_or(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.samples.last().copied().unwrap_or(0.0)
    }

    /// Median absolute deviation — robust spread for noisy bench timings.
    pub fn mad(&mut self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let med = self.median();
        let mut devs = Summary::from_slice(
            &self.samples.iter().map(|x| (x - med).abs()).collect::<Vec<_>>(),
        );
        devs.median()
    }
}

/// Simple least-squares linear fit `y = a + b·x`; used to fit latency /
/// bandwidth from (size, time) pairs when calibrating the simulator from
/// measured combiner throughput.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    assert!(n >= 2.0, "need >= 2 points");
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    assert!(denom.abs() > 1e-12, "degenerate x values");
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 5);
        assert!((w.mean() - 3.0).abs() < 1e-12);
        assert!((w.variance() - 2.5).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 5.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Summary::from_slice(&[0.0, 10.0, 20.0, 30.0, 40.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 40.0);
        assert_eq!(s.median(), 20.0);
        assert!((s.percentile(25.0) - 10.0).abs() < 1e-12);
        assert!((s.percentile(12.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let mut s = Summary::from_slice(&[1.0, 1.1, 0.9, 1.0, 100.0]);
        assert!(s.mad() < 0.2, "mad {} should ignore the outlier", s.mad());
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ci_shrinks_with_samples() {
        let mut w1 = Welford::new();
        let mut w2 = Welford::new();
        for i in 0..10 {
            w1.push((i % 3) as f64);
        }
        for i in 0..1000 {
            w2.push((i % 3) as f64);
        }
        assert!(w2.ci95_half() < w1.ci95_half());
    }

    #[test]
    fn single_sample_edge_cases() {
        let mut s = Summary::from_slice(&[7.0]);
        assert_eq!(s.median(), 7.0);
        assert_eq!(s.min(), 7.0);
        assert_eq!(s.max(), 7.0);
        assert_eq!(s.stddev(), 0.0);
    }
}
