//! Global pipeline-stage counters.
//!
//! The topology → plan → execute pipeline promises that a warm
//! [`crate::plan::PlanCache`] hit performs **zero** tree builds and
//! **zero** program compiles. That promise is only testable if the
//! expensive stages count themselves, so [`crate::tree::build_strategy_tree`]
//! and every program compiler in `collectives::programs` /
//! `collectives::extended` bump these process-wide counters. Reads and
//! increments are relaxed atomics — nanoseconds, safe to leave on in
//! release builds.
//!
//! The ghost-payload timing engine adds two more promises, counted the
//! same way: a warm tuner probe performs **zero payload-data
//! allocations** ([`count_payload_alloc`] in [`crate::netsim::Payload`]'s
//! data-materializing constructor), and a warm Fig. 8 sweep assembles its
//! rotation [`crate::plan::Schedule`] **once** per engine
//! ([`count_schedule_build`]).
//!
//! The session layer adds a final promise: the engine's per-run working
//! state (mailbox channels, wait slots, ready queue, per-rank cursors) is
//! a reusable scratch arena, so a warm step **grows no scratch storage**
//! ([`count_scratch_alloc`] in `netsim::EngineScratch::prepare`).
//!
//! Tests should compare *deltas* ([`snapshot`] before / after), never
//! absolute values: other tests in the same process also increment.

use std::sync::atomic::{AtomicU64, Ordering};

static TREE_BUILDS: AtomicU64 = AtomicU64::new(0);
static PROGRAM_COMPILES: AtomicU64 = AtomicU64::new(0);
static PLAN_CACHE_HITS: AtomicU64 = AtomicU64::new(0);
static PLAN_CACHE_MISSES: AtomicU64 = AtomicU64::new(0);
static SIM_RUNS: AtomicU64 = AtomicU64::new(0);
static PAYLOAD_ALLOCS: AtomicU64 = AtomicU64::new(0);
static SCHEDULE_BUILDS: AtomicU64 = AtomicU64::new(0);
static SCRATCH_ALLOCS: AtomicU64 = AtomicU64::new(0);

/// One strategy-tree construction (any [`crate::tree::Strategy`]).
#[inline]
pub fn count_tree_build() {
    TREE_BUILDS.fetch_add(1, Ordering::Relaxed);
}

/// One collective-program compilation (tree → simulator IR).
#[inline]
pub fn count_program_compile() {
    PROGRAM_COMPILES.fetch_add(1, Ordering::Relaxed);
}

/// A plan served from the cache without rebuilding.
#[inline]
pub fn count_plan_hit() {
    PLAN_CACHE_HITS.fetch_add(1, Ordering::Relaxed);
}

/// A plan that had to be built (cold path).
#[inline]
pub fn count_plan_miss() {
    PLAN_CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
}

/// One `netsim` engine invocation (stage 3), full or ghost mode. Lets
/// tests assert that fused schedules really execute as a *single*
/// simulation and that a tuner sweep is exactly one run per probe.
#[inline]
pub fn count_sim_run() {
    SIM_RUNS.fetch_add(1, Ordering::Relaxed);
}

/// One payload **data** materialization (an f32 segment buffer entering
/// a full [`crate::netsim::Payload`]). Ghost-mode execution never bumps
/// this — the enforcement hook behind "timing probes allocate no payload
/// data".
#[inline]
pub fn count_payload_alloc() {
    PAYLOAD_ALLOCS.fetch_add(1, Ordering::Relaxed);
}

/// One fused [`crate::plan::Schedule`] assembly
/// (`ScheduleBuilder::build`). Warm sweeps over a memoized schedule must
/// not re-assemble it.
#[inline]
pub fn count_schedule_build() {
    SCHEDULE_BUILDS.fetch_add(1, Ordering::Relaxed);
}

/// One growth of an engine scratch arena (`netsim::EngineScratch`): the
/// run about to start needed more mailbox/wait/queue/cursor capacity
/// than the arena held. Warm steps against a session- or engine-held
/// arena must never bump this — the enforcement hook behind "warm ghost
/// probes are allocation-free end to end".
#[inline]
pub fn count_scratch_alloc() {
    SCRATCH_ALLOCS.fetch_add(1, Ordering::Relaxed);
}

/// Point-in-time view of all pipeline counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Snapshot {
    pub tree_builds: u64,
    pub program_compiles: u64,
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    pub sim_runs: u64,
    pub payload_allocs: u64,
    pub schedule_builds: u64,
    pub scratch_allocs: u64,
}

impl Snapshot {
    /// Counter increments between `earlier` and `self`.
    pub fn since(&self, earlier: &Snapshot) -> Snapshot {
        Snapshot {
            tree_builds: self.tree_builds - earlier.tree_builds,
            program_compiles: self.program_compiles - earlier.program_compiles,
            plan_cache_hits: self.plan_cache_hits - earlier.plan_cache_hits,
            plan_cache_misses: self.plan_cache_misses - earlier.plan_cache_misses,
            sim_runs: self.sim_runs - earlier.sim_runs,
            payload_allocs: self.payload_allocs - earlier.payload_allocs,
            schedule_builds: self.schedule_builds - earlier.schedule_builds,
            scratch_allocs: self.scratch_allocs - earlier.scratch_allocs,
        }
    }
}

/// Read every counter at once.
pub fn snapshot() -> Snapshot {
    Snapshot {
        tree_builds: TREE_BUILDS.load(Ordering::Relaxed),
        program_compiles: PROGRAM_COMPILES.load(Ordering::Relaxed),
        plan_cache_hits: PLAN_CACHE_HITS.load(Ordering::Relaxed),
        plan_cache_misses: PLAN_CACHE_MISSES.load(Ordering::Relaxed),
        sim_runs: SIM_RUNS.load(Ordering::Relaxed),
        payload_allocs: PAYLOAD_ALLOCS.load(Ordering::Relaxed),
        schedule_builds: SCHEDULE_BUILDS.load(Ordering::Relaxed),
        scratch_allocs: SCRATCH_ALLOCS.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn increments_are_visible_in_deltas() {
        let before = snapshot();
        count_tree_build();
        count_program_compile();
        count_program_compile();
        count_plan_hit();
        count_plan_miss();
        count_sim_run();
        count_payload_alloc();
        count_schedule_build();
        count_scratch_alloc();
        let delta = snapshot().since(&before);
        // Other tests run concurrently in this process, so the deltas are
        // lower bounds, not exact counts.
        assert!(delta.tree_builds >= 1);
        assert!(delta.program_compiles >= 2);
        assert!(delta.plan_cache_hits >= 1);
        assert!(delta.plan_cache_misses >= 1);
        assert!(delta.sim_runs >= 1);
        assert!(delta.payload_allocs >= 1);
        assert!(delta.schedule_builds >= 1);
        assert!(delta.scratch_allocs >= 1);
    }
}
