//! Minimal property-based testing kit (the offline vendor set has no
//! `proptest`/`quickcheck`, so the harness is part of the codebase).
//!
//! Model: a *sized generator* `Fn(&mut Rng, usize) -> T` produces a random
//! case whose complexity grows with the size parameter; the runner sweeps
//! sizes from small to `max_size` across `cases` runs. On failure it
//! re-searches downward for the smallest failing size and smallest seed
//! found within a bounded budget, then panics with a replayable
//! `(seed, size)` pair.

use super::rng::Rng;

/// Runner configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; every case derives its own stream from it.
    pub seed: u64,
    /// Maximum size parameter (cases sweep 1..=max_size cyclically-ish).
    pub max_size: usize,
    /// Shrink search budget (number of re-generations).
    pub shrink_budget: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 200, seed: 0xC0FFEE, max_size: 64, shrink_budget: 400 }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
    pub fn max_size(mut self, n: usize) -> Self {
        self.max_size = n;
        self
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// A failing case report.
#[derive(Debug)]
pub struct Failure {
    pub name: String,
    pub seed: u64,
    pub case_index: usize,
    pub size: usize,
    pub message: String,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property '{}' failed (replay: seed={:#x} case={} size={}): {}",
            self.name, self.seed, self.case_index, self.size, self.message
        )
    }
}

/// Run `prop` over `cfg.cases` generated inputs; panic on the (shrunk)
/// first failure. `gen` must be deterministic in `(rng, size)`.
pub fn check<T, G, P>(name: &str, cfg: Config, gen: G, prop: P)
where
    G: Fn(&mut Rng, usize) -> T,
    P: Fn(&T) -> PropResult,
{
    if let Some(f) = check_quiet(name, &cfg, &gen, &prop) {
        panic!("{f}");
    }
}

/// Like [`check`] but returns the failure instead of panicking (used to
/// test the kit itself).
pub fn check_quiet<T, G, P>(name: &str, cfg: &Config, gen: &G, prop: &P) -> Option<Failure>
where
    G: Fn(&mut Rng, usize) -> T,
    P: Fn(&T) -> PropResult,
{
    let mut master = Rng::new(cfg.seed);
    for case_index in 0..cfg.cases {
        // Sweep sizes: start tiny, reach max_size by the end of the run.
        let size = 1 + (case_index * cfg.max_size) / cfg.cases.max(1);
        let case_seed = master.next_u64();
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng, size);
        if let Err(message) = prop(&input) {
            let shrunk = shrink(cfg, gen, prop, case_seed, size, message);
            return Some(Failure { name: name.to_string(), case_index, ..shrunk });
        }
    }
    None
}

/// Search smaller (seed, size) pairs for a simpler failing case.
fn shrink<T, G, P>(
    cfg: &Config,
    gen: &G,
    prop: &P,
    seed: u64,
    size: usize,
    message: String,
) -> Failure
where
    G: Fn(&mut Rng, usize) -> T,
    P: Fn(&T) -> PropResult,
{
    let mut best_size = size;
    let mut best_seed = seed;
    let mut best_msg = message;
    let mut budget = cfg.shrink_budget;
    // Phase 1: shrink the size with the original seed, halving down.
    let mut s = size / 2;
    while s >= 1 && budget > 0 {
        budget -= 1;
        let mut rng = Rng::new(best_seed);
        let input = gen(&mut rng, s);
        if let Err(m) = prop(&input) {
            best_size = s;
            best_msg = m;
            s /= 2;
        } else if s + 1 < best_size {
            s += (best_size - s) / 2; // bisect back up
            if s <= best_size / 2 {
                break;
            }
        } else {
            break;
        }
    }
    // Phase 2: try alternate seeds at the best size (often finds tidier cases).
    let mut reseeder = Rng::new(best_seed ^ 0x5EED);
    while budget > 0 {
        budget -= 1;
        let cand = reseeder.next_u64();
        let mut rng = Rng::new(cand);
        let input = gen(&mut rng, best_size);
        if let Err(m) = prop(&input) {
            best_seed = cand;
            best_msg = m;
            break; // one alternate is enough; keep it deterministic & fast
        }
    }
    Failure { name: String::new(), seed: best_seed, case_index: 0, size: best_size, message: best_msg }
}

/// Convenience: assert two f32 slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_returns_none() {
        let cfg = Config::default().cases(50);
        let out = check_quiet(
            "sum-commutes",
            &cfg,
            &|r: &mut Rng, size| {
                (0..size).map(|_| r.usize_in(0, 100) as i64).collect::<Vec<_>>()
            },
            &|xs: &Vec<i64>| {
                let mut rev = xs.clone();
                rev.reverse();
                if xs.iter().sum::<i64>() == rev.iter().sum::<i64>() {
                    Ok(())
                } else {
                    Err("sum not commutative".into())
                }
            },
        );
        assert!(out.is_none());
    }

    #[test]
    fn failing_property_is_caught_and_shrunk() {
        let cfg = Config::default().cases(200).max_size(64);
        let out = check_quiet(
            "no-vec-longer-than-10",
            &cfg,
            &|r: &mut Rng, size| (0..size).map(|_| r.next_u64()).collect::<Vec<_>>(),
            &|xs: &Vec<u64>| {
                if xs.len() <= 10 {
                    Ok(())
                } else {
                    Err(format!("len {}", xs.len()))
                }
            },
        );
        let f = out.expect("must fail");
        // Shrinker should find a size close to the boundary (11), well
        // below max_size.
        assert!(f.size <= 32, "shrunk size {} too large", f.size);
    }

    #[test]
    fn failure_is_replayable() {
        let cfg = Config::default().cases(100);
        let gen = |r: &mut Rng, size: usize| r.usize_in(0, size.max(1) + 1);
        let prop = |x: &usize| if *x < 5 { Ok(()) } else { Err(format!("{x} >= 5")) };
        let f = check_quiet("replay", &cfg, &gen, &prop).expect("must fail");
        // Re-generate with the reported seed/size: must fail again.
        let mut rng = Rng::new(f.seed);
        let input = gen(&mut rng, f.size);
        assert!(prop(&input).is_err(), "replay did not reproduce");
    }

    #[test]
    fn assert_close_detects_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-6, 1e-6).is_err());
    }
}
