//! Deterministic pseudo-random number generators.
//!
//! The offline vendor set has no `rand` crate, so we implement the two
//! small, well-known generators the library needs: SplitMix64 (seeding,
//! stream splitting) and Xoshiro256** (bulk generation). Both are
//! reproducible across platforms — simulator runs and property tests are
//! seeded and replayable.

/// SplitMix64: tiny, robust seeder / splitter (Steele et al.).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast general-purpose PRNG (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent child stream (for per-rank / per-case RNGs).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Unbiased: rejection-sample the low product half.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "usize_in: empty range {lo}..{hi}");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose from empty slice");
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Rng::new(11);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let a: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn mean_roughly_half() {
        let mut r = Rng::new(123);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }
}
