//! Shared utilities: deterministic RNG, statistics, formatting, and the
//! property-testing kit. All substrates (no external crates beyond `xla`
//! and `anyhow` are available offline — see DESIGN.md §2).

pub mod counters;
pub mod fmt;
pub mod json;
pub mod par;
pub mod propcheck;
pub mod rng;
pub mod stats;
