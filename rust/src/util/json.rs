//! Minimal JSON reader/writer (no `serde` in the offline vendor set).
//!
//! Exists for the small machine-readable files this crate persists —
//! above all the versioned [`crate::session::PolicyTable`] format written
//! by `gridcollect tune-boundary --save` and consumed via
//! `--policy-file`. The writer side stays hand-rolled at each call site
//! (like `benchkit::save_bench_json`); this module supplies the missing
//! half, a strict recursive-descent parser into a [`Value`] tree, plus
//! the string-escaping helper writers share.
//!
//! Scope: the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null). Numbers are parsed as `f64` —
//! exact for every integer this crate writes (payload sizes, versions);
//! 64-bit hashes are therefore serialized as hex *strings*, never as
//! JSON numbers.

use crate::error::{Error, Result};

/// A parsed JSON value. Object keys keep their file order.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Member of an object by key (`None` for missing keys or non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integral number in `u64` range (rejects fractions and negatives).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a JSON document (quotes excluded).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Parse one JSON document; trailing non-whitespace is an error.
pub fn parse(src: &str) -> Result<Value> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after JSON document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("JSON parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            members.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(members));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // Fast path: copy the maximal escape-free, ASCII-safe run.
            while let Some(c) = self.peek() {
                if c == b'"' || c == b'\\' || c < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            if self.pos > start {
                // The slice boundaries sit on byte values < 0x80, so this
                // is always valid UTF-8 if the input was.
                let run = std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| self.err("invalid UTF-8 in string"))?;
                out.push_str(run);
            }
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("truncated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs are not emitted by our writers.
                            let c = char::from_u32(code)
                                .ok_or_else(|| self.err("unsupported \\u code point"))?;
                            out.push(c);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => return Err(self.err("control character in string")),
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        let v = parse(r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e2}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        let arr = v.get("b").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_bool(), Some(true));
        assert_eq!(arr[1], Value::Null);
        assert_eq!(arr[2].as_str(), Some("x\ny"));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-250.0));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn escape_round_trips_through_parse() {
        let nasty = "quote\" slash\\ newline\n tab\t unicode é";
        let doc = format!("{{\"k\": \"{}\"}}", escape(nasty));
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some(nasty));
    }

    #[test]
    fn u64_accessor_rejects_fractions() {
        assert_eq!(parse("3.5").unwrap().as_u64(), None);
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\" 1}",
            "{\"a\": 1} trailing",
            "\"unterminated",
            "nul",
            "{\"a\": 01x}",
        ] {
            assert!(parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn parses_benchkit_style_output() {
        // The shape save_bench_json emits — the parser must read our own
        // writers.
        let doc = r#"{
  "bench": "engine_throughput",
  "results": [
    {"name": "a/b", "iters": 3, "mean_us": 1.500},
    {"name": "c", "iters": 1, "mean_us": 2.000}
  ]
}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("results").unwrap().as_array().unwrap().len(), 2);
    }
}
