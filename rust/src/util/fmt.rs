//! Human-readable formatting and simple table writers (markdown / CSV)
//! used by the experiment reports and the bench harness.

/// Format a byte count with binary units ("12.0 KiB").
pub fn bytes(n: usize) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format a duration given in microseconds ("1.50 ms", "2.00 s").
pub fn time_us(us: f64) -> String {
    if us < 0.0 {
        return format!("-{}", time_us(-us));
    }
    if us < 1e3 {
        format!("{us:.2} us")
    } else if us < 1e6 {
        format!("{:.2} ms", us / 1e3)
    } else {
        format!("{:.2} s", us / 1e6)
    }
}

/// Format a rate in MB/s from (bytes, microseconds).
pub fn rate(bytes: usize, us: f64) -> String {
    if us <= 0.0 {
        return "inf".into();
    }
    let mbps = bytes as f64 / us; // bytes/us == MB/s
    format!("{mbps:.1} MB/s")
}

/// A simple table that renders to aligned markdown or CSV.
#[derive(Clone, Debug)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width != header width");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Aligned GitHub-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }

    /// RFC-4180-ish CSV (quotes cells containing commas/quotes/newlines).
    pub fn to_csv(&self) -> String {
        fn esc(s: &str) -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        }
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(17), "17 B");
        assert_eq!(bytes(2048), "2.0 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn time_units() {
        assert_eq!(time_us(12.0), "12.00 us");
        assert_eq!(time_us(1500.0), "1.50 ms");
        assert_eq!(time_us(2_000_000.0), "2.00 s");
    }

    #[test]
    fn rate_mbps() {
        assert_eq!(rate(1_000_000, 1_000_000.0), "1.0 MB/s");
    }

    #[test]
    fn markdown_alignment_and_shape() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["a", "1"]);
        t.row_strs(&["longer", "22"]);
        let md = t.to_markdown();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("| name   |"));
        assert!(lines[1].starts_with("|---"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["x,y", "he said \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a"]);
        t.row_strs(&["1", "2"]);
    }
}
