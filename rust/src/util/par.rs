//! Minimal scoped-thread fan-out for the driver layer (tuner probes,
//! sweep points). No work-stealing, no channels: `n` independent tasks
//! are claimed off an atomic counter by up to `threads` workers, each
//! holding one worker-local state (a pooled `SimResult`, a ghost
//! prober, ...) for its whole run — so per-task allocations stay as
//! pooled as the serial loop's. Results land in index order, making the
//! fan-out's output byte-identical to the serial loop's.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Run `f(state, i)` for every `i in 0..n` and collect the results in
/// index order. `mk` builds one worker-local state per worker (called
/// once per worker, not per task). `threads <= 1` or `n <= 1` runs the
/// serial loop inline — same closures, no thread machinery — so serial
/// and parallel callers share one code path for the work itself.
pub fn map_pooled<S, T, G, F>(threads: usize, n: usize, mk: G, f: F) -> Vec<T>
where
    S: Send,
    T: Send,
    G: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        let mut state = mk();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = threads.min(n);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let slots = &slots;
            let next = &next;
            let mk = &mk;
            let f = &f;
            scope.spawn(move || {
                let mut state = mk();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        return;
                    }
                    *slots[i].lock().unwrap() = Some(f(&mut state, i));
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every index claimed exactly once"))
        .collect()
}

/// [`map_pooled`] without worker-local state.
pub fn map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    map_pooled(threads, n, || (), |(), i| f(i))
}

type Job<S> = Box<dyn FnOnce(&mut S) + Send>;

struct PoolQueue<S> {
    jobs: VecDeque<Job<S>>,
    closing: bool,
}

struct PoolShared<S> {
    queue: Mutex<PoolQueue<S>>,
    cv: Condvar,
}

/// The long-lived sibling of [`map_pooled`]: a bounded pool of workers,
/// each holding one worker-local state for its whole lifetime (the
/// `gridd` service hands every worker its own `ExecScratch` arena),
/// draining submitted jobs from one FIFO queue. Dropping the pool (or
/// calling [`TaskPool::join`]) closes the queue, drains every job
/// already submitted, and joins the workers — nothing accepted is ever
/// silently dropped.
pub struct TaskPool<S: Send + 'static> {
    shared: Arc<PoolShared<S>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl<S: Send + 'static> TaskPool<S> {
    /// Spawn `threads` workers (at least one), worker `w` owning the
    /// state `mk(w)` — called once per worker, on that worker's thread,
    /// exactly like [`map_pooled`]'s `mk`.
    pub fn new<G>(threads: usize, mk: G) -> Self
    where
        G: Fn(usize) -> S + Send + Sync + 'static,
    {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(PoolQueue { jobs: VecDeque::new(), closing: false }),
            cv: Condvar::new(),
        });
        let mk = Arc::new(mk);
        let workers = (0..threads.max(1))
            .map(|w| {
                let shared = Arc::clone(&shared);
                let mk = Arc::clone(&mk);
                std::thread::spawn(move || {
                    let mut state = mk(w);
                    loop {
                        let job = {
                            let mut q = shared.queue.lock().unwrap();
                            loop {
                                if let Some(job) = q.jobs.pop_front() {
                                    break Some(job);
                                }
                                if q.closing {
                                    break None;
                                }
                                q = shared.cv.wait(q).unwrap();
                            }
                        };
                        match job {
                            // A panicking job must not kill the worker:
                            // in a long-lived pool (the gridd service's
                            // connection pool) each dead worker would
                            // silently shrink capacity until nothing is
                            // served. States are worker-owned, so
                            // AssertUnwindSafe is sound — the next job
                            // sees whatever the panicked one left, same
                            // as any other shared scratch.
                            Some(job) => {
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| job(&mut state)),
                                );
                            }
                            None => return,
                        }
                    }
                })
            })
            .collect();
        TaskPool { shared, workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job for the next idle worker. Jobs submitted after the
    /// pool started closing are rejected (returns `false`) rather than
    /// queued where no worker will ever claim them.
    pub fn submit(&self, job: impl FnOnce(&mut S) + Send + 'static) -> bool {
        let mut q = self.shared.queue.lock().unwrap();
        if q.closing {
            return false;
        }
        q.jobs.push_back(Box::new(job));
        drop(q);
        self.shared.cv.notify_one();
        true
    }

    /// Close the queue, drain every already-submitted job, and join the
    /// workers (also what dropping the pool does).
    pub fn join(self) {
        drop(self);
    }
}

impl<S: Send + 'static> Drop for TaskPool<S> {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().closing = true;
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_land_in_index_order() {
        for threads in [1usize, 2, 4, 8] {
            let got = map(threads, 17, |i| i * i);
            assert_eq!(got, (0..17).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn worker_state_is_reused_across_tasks() {
        // Each worker's state counts the tasks it ran; the total over
        // all workers must be n, and with one thread a single state
        // sees every task.
        let n = 23;
        let got = map_pooled(
            1,
            n,
            || 0usize,
            |seen, i| {
                *seen += 1;
                (*seen, i)
            },
        );
        assert_eq!(got.len(), n);
        assert_eq!(got.last().unwrap().0, n, "one state served every task");
        let par = map_pooled(4, n, || 0usize, |seen, _| {
            *seen += 1;
            1usize
        });
        assert_eq!(par.iter().sum::<usize>(), n);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        assert!(map(8, 0, |i| i).is_empty());
        assert_eq!(map(8, 1, |i| i + 1), vec![1]);
    }

    #[test]
    fn task_pool_drains_every_submitted_job_on_join() {
        use std::sync::atomic::AtomicUsize;
        let done = Arc::new(AtomicUsize::new(0));
        let pool = TaskPool::new(4, |_w| ());
        assert_eq!(pool.threads(), 4);
        for _ in 0..100 {
            let done = Arc::clone(&done);
            assert!(pool.submit(move |()| {
                done.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.join();
        assert_eq!(done.load(Ordering::Relaxed), 100, "join drains the queue");
    }

    #[test]
    fn task_pool_worker_state_is_reused_across_jobs() {
        // Worker-local state survives between jobs (the whole point:
        // scratch arenas warm up once per worker, not once per job).
        let totals = Arc::new(Mutex::new(Vec::new()));
        let pool = {
            let totals = Arc::clone(&totals);
            TaskPool::new(2, move |w| (w, 0usize, Arc::clone(&totals)))
        };
        for _ in 0..40 {
            pool.submit(|state: &mut (usize, usize, Arc<Mutex<Vec<(usize, usize)>>>)| {
                state.1 += 1;
                let count = state.1;
                state.2.lock().unwrap().push((state.0, count));
            });
        }
        pool.join();
        let log = totals.lock().unwrap();
        assert_eq!(log.len(), 40);
        // Per-worker counts are cumulative — proof the state persisted.
        let max_per_worker: usize =
            (0..2).map(|w| log.iter().filter(|(lw, _)| *lw == w).count()).max().unwrap();
        assert!(log.iter().any(|&(_, c)| c == max_per_worker));
        let sum: usize = (0..2)
            .map(|w| log.iter().filter(|(lw, _)| *lw == w).map(|&(_, c)| c).max().unwrap_or(0))
            .sum();
        assert_eq!(sum, 40, "every job ran on exactly one worker's state");
    }

    #[test]
    fn task_pool_survives_panicking_jobs() {
        // A single worker that hits a panicking job must keep serving
        // the jobs behind it — the pool must not shrink to zero.
        let done = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let pool = TaskPool::new(1, |_w| ());
        assert!(pool.submit(|()| panic!("job blew up")));
        for _ in 0..5 {
            let done = Arc::clone(&done);
            assert!(pool.submit(move |()| {
                done.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.join();
        assert_eq!(done.load(Ordering::Relaxed), 5, "worker survived the panic");
    }

    #[test]
    fn task_pool_spawns_at_least_one_worker() {
        let pool = TaskPool::new(0, |_w| ());
        assert_eq!(pool.threads(), 1);
        let ran = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let flag = Arc::clone(&ran);
        pool.submit(move |()| flag.store(true, Ordering::Relaxed));
        pool.join();
        assert!(ran.load(Ordering::Relaxed));
    }
}
