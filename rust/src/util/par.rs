//! Minimal scoped-thread fan-out for the driver layer (tuner probes,
//! sweep points). No work-stealing, no channels: `n` independent tasks
//! are claimed off an atomic counter by up to `threads` workers, each
//! holding one worker-local state (a pooled `SimResult`, a ghost
//! prober, ...) for its whole run — so per-task allocations stay as
//! pooled as the serial loop's. Results land in index order, making the
//! fan-out's output byte-identical to the serial loop's.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(state, i)` for every `i in 0..n` and collect the results in
/// index order. `mk` builds one worker-local state per worker (called
/// once per worker, not per task). `threads <= 1` or `n <= 1` runs the
/// serial loop inline — same closures, no thread machinery — so serial
/// and parallel callers share one code path for the work itself.
pub fn map_pooled<S, T, G, F>(threads: usize, n: usize, mk: G, f: F) -> Vec<T>
where
    S: Send,
    T: Send,
    G: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        let mut state = mk();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = threads.min(n);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let slots = &slots;
            let next = &next;
            let mk = &mk;
            let f = &f;
            scope.spawn(move || {
                let mut state = mk();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        return;
                    }
                    *slots[i].lock().unwrap() = Some(f(&mut state, i));
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every index claimed exactly once"))
        .collect()
}

/// [`map_pooled`] without worker-local state.
pub fn map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    map_pooled(threads, n, || (), |(), i| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_land_in_index_order() {
        for threads in [1usize, 2, 4, 8] {
            let got = map(threads, 17, |i| i * i);
            assert_eq!(got, (0..17).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn worker_state_is_reused_across_tasks() {
        // Each worker's state counts the tasks it ran; the total over
        // all workers must be n, and with one thread a single state
        // sees every task.
        let n = 23;
        let got = map_pooled(
            1,
            n,
            || 0usize,
            |seen, i| {
                *seen += 1;
                (*seen, i)
            },
        );
        assert_eq!(got.len(), n);
        assert_eq!(got.last().unwrap().0, n, "one state served every task");
        let par = map_pooled(4, n, || 0usize, |seen, _| {
            *seen += 1;
            1usize
        });
        assert_eq!(par.iter().sum::<usize>(), n);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        assert!(map(8, 0, |i| i).is_empty());
        assert_eq!(map(8, 1, |i| i + 1), vec![1]);
    }
}
