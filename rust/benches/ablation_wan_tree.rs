//! Bench E9 — §6 ablation: the tree shape used at the WAN level (flat —
//! the paper's choice — vs binomial, chain, generalized Fibonacci) across
//! message sizes and site counts. Quantifies the §6 observation that the
//! optimal shape depends on the latency/bandwidth regime: flat wins while
//! latency dominates, pipelined/binomial shapes win once the root's
//! uplink serializes large payloads.
//!
//! Run: `cargo bench --bench ablation_wan_tree`

use gridcollect::benchkit::{save_report, section};
use gridcollect::coordinator::experiment;
use gridcollect::model::presets;
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::{LevelPolicy, Strategy, TreeShape};
use gridcollect::util::fmt::{self, Table};

fn main() {
    for (sites, bytes) in [(8usize, 1024usize), (8, 65536), (8, 1 << 20), (16, 65536)] {
        section(&format!("E9 — WAN shape ablation: {sites} sites, {}", fmt::bytes(bytes)));
        let t = experiment::wan_shape_ablation(sites, bytes).unwrap();
        print!("{}", t.to_markdown());
        save_report(&format!("ablation_wan_{sites}sites_{bytes}"), &t);
    }

    section("E9b — λ sweep for the Fibonacci WAN stage (16 sites, 64 KiB)");
    let spec = TopologySpec::uniform(16, 1, 4).unwrap();
    let comm = Communicator::world(&spec);
    let params = presets::paper_grid();
    let data = vec![0.5f32; 16384];
    let mut t = Table::new(&["λ", "makespan"]);
    for lambda in [1u32, 2, 3, 4, 6, 8, 12, 16] {
        let policy =
            LevelPolicy { shapes: vec![TreeShape::Fibonacci(lambda), TreeShape::Binomial] };
        let session = GridSession::new(&comm, params.clone(), Strategy::Multilevel)
            .with_level_policy(policy);
        let out = session.bcast(0, &data).unwrap();
        t.row(&[lambda.to_string(), fmt::time_us(out.sim.makespan_us)]);
    }
    print!("{}", t.to_markdown());
    save_report("ablation_lambda_sweep", &t);

    section("E9c — flat WAN (paper) vs all binomial vs distance-halving (bine)");
    let mut t2 = Table::new(&[
        "msg size",
        "flat WAN (paper §3.2)",
        "all binomial ([19] prototype)",
        "distance-halving WAN (2508.17311)",
    ]);
    for bytes in [1024usize, 16384, 262144, 1 << 20] {
        let data = vec![0.5f32; bytes / 4];
        let run_policy = |policy: LevelPolicy| {
            GridSession::new(&comm, params.clone(), Strategy::Multilevel)
                .with_level_policy(policy)
                .bcast(0, &data)
                .unwrap()
                .sim
                .makespan_us
        };
        let flat = run_policy(LevelPolicy::paper());
        let bino = run_policy(LevelPolicy::all_binomial());
        let dh = run_policy(LevelPolicy {
            shapes: vec![TreeShape::DistanceHalving, TreeShape::Binomial],
        });
        t2.row(&[
            fmt::bytes(bytes),
            fmt::time_us(flat),
            fmt::time_us(bino),
            fmt::time_us(dh),
        ]);
    }
    print!("{}", t2.to_markdown());
    save_report("ablation_policy", &t2);
}
