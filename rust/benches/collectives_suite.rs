//! Bench E8 — all five implemented collectives (§3: Bcast, Reduce,
//! Barrier, Gather, Scatter) under the four strategies, at small and
//! large payloads, with wall-clock timings of the full simulate+verify
//! path.
//!
//! Run: `cargo bench --bench collectives_suite`

use gridcollect::benchkit::{save_report, section, Bench};
use gridcollect::coordinator::experiment;
use gridcollect::netsim::ReduceOp;
use gridcollect::session::GridSession;
use gridcollect::tree::Strategy;
use gridcollect::util::fmt;

fn main() {
    for bytes in [4096usize, 262144] {
        section(&format!("E8 — five ops x four strategies at {}", fmt::bytes(bytes)));
        let t = experiment::collectives_suite_table(bytes, experiment::native_arc()).unwrap();
        print!("{}", t.to_markdown());
        save_report(&format!("collectives_suite_{bytes}"), &t);
    }

    section("wall-clock of one collective simulation (48 ranks, 64 KiB)");
    let comm = experiment::paper_comm();
    let params = experiment::paper_params();
    let n = comm.size();
    let bench = Bench::default();
    let engine = GridSession::new(&comm, params, Strategy::Multilevel);
    let data = vec![1.0f32; 16384];
    let contributions: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; 16384]).collect();
    let segs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; 512]).collect();
    bench.run("sim-wall/bcast", || {
        std::hint::black_box(engine.bcast(0, &data).unwrap().sim.makespan_us);
    });
    bench.run("sim-wall/reduce", || {
        std::hint::black_box(
            engine.reduce(0, ReduceOp::Sum, &contributions).unwrap().sim.makespan_us,
        );
    });
    bench.run("sim-wall/barrier", || {
        std::hint::black_box(engine.barrier().unwrap().makespan_us);
    });
    bench.run("sim-wall/gather", || {
        std::hint::black_box(engine.gather(0, &segs).unwrap().sim.makespan_us);
    });
    bench.run("sim-wall/scatter", || {
        std::hint::black_box(engine.scatter(0, &segs).unwrap().sim.makespan_us);
    });
    bench.run("sim-wall/allreduce", || {
        std::hint::black_box(
            engine.allreduce(ReduceOp::Sum, &contributions).unwrap().sim.makespan_us,
        );
    });
}
