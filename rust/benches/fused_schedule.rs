//! Bench E13 — fused vs separate Fig. 7 rotation, cold and warm.
//!
//! "Separate" is the pre-fusion timing app: 2n `netsim::run` invocations
//! per point (one per broadcast, one per ack-barrier). "Fused" assembles
//! the whole rotation into one Schedule and runs a single simulation.
//! Cold includes the tree builds + compiles of a fresh plan cache; warm
//! reuses a long-lived engine, so fused is pure payload setup + schedule
//! assembly + one run.
//!
//! Run: `cargo bench --bench fused_schedule`
//! Smoke (CI): `cargo bench --bench fused_schedule -- --smoke`
//! Reports land in `target/bench-reports/` (md/csv + BENCH_*.json).

use gridcollect::benchkit::{save_bench_json, save_report, section, Bench};
use gridcollect::coordinator::{experiment, timing_app};
use gridcollect::netsim::ReduceOp;
use gridcollect::plan::{AlgoPolicy, AllreduceAlgo};
use gridcollect::session::GridSession;
use gridcollect::tree::Strategy;
use gridcollect::util::fmt::{self, Table};
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let bench = if smoke {
        // 1 sample: CI smoke mode only checks the harness runs end to end.
        Bench { warmup_iters: 0, min_iters: 1, max_iters: 1, target: Duration::ZERO }
    } else {
        Bench::default()
    };
    let sizes: Vec<usize> = if smoke { vec![65536] } else { vec![4096, 65536, 1 << 20] };

    let comm = experiment::paper_comm();
    let params = experiment::paper_params();
    let mut results = Vec::new();

    section("fused vs separate rotation — cold (fresh engine per iteration)");
    for &bytes in &sizes {
        results.push(bench.run(&format!("rotation/cold/fused/{}", fmt::bytes(bytes)), || {
            let s = GridSession::new(&comm, params.clone(), Strategy::Multilevel);
            let p = timing_app::run_point_with(&s, bytes).unwrap();
            std::hint::black_box(p.total_us);
        }));
        results.push(bench.run(
            &format!("rotation/cold/separate/{}", fmt::bytes(bytes)),
            || {
                let s = GridSession::new(&comm, params.clone(), Strategy::Multilevel);
                let p = timing_app::run_point_separate(&s, bytes).unwrap();
                std::hint::black_box(p.total_us);
            },
        ));
    }

    section("fused vs separate rotation — warm (long-lived engine)");
    let session = GridSession::new(&comm, params.clone(), Strategy::Multilevel);
    timing_app::run_point_with(&session, sizes[0]).unwrap(); // prime the plan cache
    for &bytes in &sizes {
        results.push(bench.run(&format!("rotation/warm/fused/{}", fmt::bytes(bytes)), || {
            let p = timing_app::run_point_with(&session, bytes).unwrap();
            std::hint::black_box(p.total_us);
        }));
        results.push(bench.run(
            &format!("rotation/warm/separate/{}", fmt::bytes(bytes)),
            || {
                let p = timing_app::run_point_separate(&session, bytes).unwrap();
                std::hint::black_box(p.total_us);
            },
        ));
    }

    section("hybrid allreduce — fused per-level plan vs the uniform compositions");
    let n = comm.size();
    let policies = [
        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
        AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
        AlgoPolicy::hybrid(1),
    ];
    let mut hybrid_delta =
        Table::new(&["msg size", "policy", "makespan", "WAN msgs", "total msgs"]);
    for &bytes in &sizes {
        let contributions: Vec<Vec<f32>> =
            (0..n).map(|r| vec![r as f32; bytes / 4]).collect();
        for policy in policies {
            // Cold: fresh engine per iteration — composes the hybrid plan
            // from scratch (cached reduce phase + delivery compile).
            results.push(bench.run(
                &format!("allreduce/cold/{}/{}", policy.name(), fmt::bytes(bytes)),
                || {
                    let s = GridSession::new(&comm, params.clone(), Strategy::Multilevel);
                    let o = s
                        .allreduce_with_policy(policy, 0, ReduceOp::Sum, &contributions)
                        .unwrap();
                    std::hint::black_box(o.sim.makespan_us);
                },
            ));
            // Warm: long-lived session — pure payload setup + one run.
            let s = GridSession::new(&comm, params.clone(), Strategy::Multilevel);
            s.allreduce_with_policy(policy, 0, ReduceOp::Sum, &contributions).unwrap();
            results.push(bench.run(
                &format!("allreduce/warm/{}/{}", policy.name(), fmt::bytes(bytes)),
                || {
                    let o = s
                        .allreduce_with_policy(policy, 0, ReduceOp::Sum, &contributions)
                        .unwrap();
                    std::hint::black_box(o.sim.makespan_us);
                },
            ));
            let o = s.allreduce_with_policy(policy, 0, ReduceOp::Sum, &contributions).unwrap();
            hybrid_delta.row(&[
                fmt::bytes(bytes),
                policy.name(),
                fmt::time_us(o.sim.makespan_us),
                o.sim.wan_messages().to_string(),
                o.sim.msgs_by_sep.iter().sum::<u64>().to_string(),
            ]);
        }
    }
    print!("{}", hybrid_delta.to_markdown());
    save_report("hybrid_allreduce", &hybrid_delta);

    section("virtual-time delta (the §4 fidelity gap the fusion closes)");
    let delta = experiment::fig8_fused_vs_separate(&sizes, Strategy::Multilevel).unwrap();
    print!("{}", delta.to_markdown());
    save_report("fused_vs_separate", &delta);

    let mut wall = Table::new(&["case", "median us", "mean us", "iters"]);
    for r in &results {
        wall.row(&[
            r.name.clone(),
            format!("{:.1}", r.median_us),
            format!("{:.1}", r.mean_us),
            r.iters.to_string(),
        ]);
    }
    save_report("fused_schedule_wall", &wall);
    save_bench_json("fused_schedule", &results);
}
