//! Bench E16 — `gridd` daemon throughput: cold tune sweeps, warm
//! resolve/allreduce service rates on one connection, and contended
//! resolve QPS with 8 concurrent clients. The warm phase re-asserts the
//! zero-build / zero-allocation counters in bench context (this binary
//! is its own process, so exact deltas are safe).
//!
//! Run: `cargo bench --bench gridd_qps`
//! Smoke (CI): `cargo bench --bench gridd_qps -- --smoke`
//! Reports land in `target/bench-reports/` (md/csv + BENCH_*.json).

use gridcollect::benchkit::{save_bench_json, save_report, section, Bench};
use gridcollect::service::{proto::JsonObj, Client, Gridd, GriddConfig, Target};
use gridcollect::util::counters;
use gridcollect::util::fmt::Table;
use std::time::Duration;

const CONTENDED_CLIENTS: usize = 8;
const REQUESTS_PER_CLIENT: usize = 20;

fn connect(socket: &str) -> Client {
    Client::connect(&Target::parse(socket)).unwrap()
}

fn tune_request(bytes: usize) -> String {
    JsonObj::new().str("cmd", "tune").num_usize("bytes", bytes).render()
}

fn resolve_request(bytes: usize) -> String {
    JsonObj::new().str("cmd", "resolve").num_usize("bytes", bytes).render()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let bench = if smoke {
        // 1 sample: CI smoke mode only checks the harness runs end to end.
        Bench { warmup_iters: 0, min_iters: 1, max_iters: 1, target: Duration::ZERO }
    } else {
        Bench::quick()
    };

    let socket = std::env::temp_dir()
        .join(format!("gridd_qps_{}.sock", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let cfg = GriddConfig {
        socket: Some(socket.clone()),
        tcp: None,
        threads: CONTENDED_CLIENTS,
        policy_dir: None,
    };
    let handle = Gridd::new(cfg).unwrap().spawn();
    let mut results = Vec::new();

    section("E16a — cold tune: one full boundary sweep per request");
    // Every iteration asks a size the daemon has never seen, so each
    // request is a fresh singleflight leader running a real sweep.
    let mut c = connect(&socket);
    let mut next_cold = 1 << 22;
    results.push(bench.run("gridd/tune_cold", || {
        next_cold += 4;
        let doc = c.request(&tune_request(next_cold)).unwrap();
        assert_eq!(doc.get("source").and_then(|v| v.as_str()), Some("tuned"));
    }));

    section("E16b — warm service rates on one connection");
    let warm_bytes = 65536;
    c.request(&tune_request(warm_bytes)).unwrap();
    let allreduce = JsonObj::new().str("cmd", "allreduce").num_usize("bytes", warm_bytes).render();
    c.request(&allreduce).unwrap(); // prime this worker's scratch arena
    let before = counters::snapshot();
    results.push(bench.run("gridd/resolve_warm", || {
        let doc = c.request(&resolve_request(warm_bytes)).unwrap();
        assert_eq!(doc.get("exact").and_then(|v| v.as_bool()), Some(true));
    }));
    results.push(bench.run("gridd/allreduce_warm", || {
        c.request(&allreduce).unwrap();
    }));
    let warm = counters::snapshot().since(&before);
    assert_eq!(warm.tree_builds, 0, "warm daemon requests build no trees");
    assert_eq!(warm.program_compiles, 0, "warm daemon requests compile nothing");
    assert_eq!(warm.plan_cache_misses, 0, "the tuned plan stays cached");
    assert_eq!(warm.payload_allocs, 0, "ghost timing allocates no payload data");
    assert_eq!(warm.scratch_allocs, 0, "the worker's scratch arena is already sized");
    drop(c);

    section("E16c — contended resolve: 8 clients per iteration");
    let batch = CONTENDED_CLIENTS * REQUESTS_PER_CLIENT;
    results.push(bench.run("gridd/resolve_contended_8x", || {
        let workers: Vec<_> = (0..CONTENDED_CLIENTS)
            .map(|_| {
                let socket = socket.clone();
                std::thread::spawn(move || {
                    let mut c = connect(&socket);
                    for _ in 0..REQUESTS_PER_CLIENT {
                        c.request(&resolve_request(warm_bytes)).unwrap();
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
    }));

    connect(&socket).request(&JsonObj::new().str("cmd", "shutdown").render()).unwrap();
    handle.join().unwrap();

    let mut table = Table::new(&["case", "median us", "mean us", "iters", "QPS"]);
    for r in &results {
        // The contended case runs a whole batch per iteration; the
        // others are one request per iteration.
        let per_iter = if r.name.contains("contended") { batch as f64 } else { 1.0 };
        table.row(&[
            r.name.clone(),
            format!("{:.1}", r.median_us),
            format!("{:.1}", r.mean_us),
            r.iters.to_string(),
            format!("{:.0}", per_iter * 1e6 / r.mean_us),
        ]);
    }
    print!("{}", table.to_markdown());
    save_report("gridd_qps", &table);
    save_bench_json("gridd_qps", &results);
}
