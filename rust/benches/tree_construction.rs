//! Bench E12a — wall-clock cost of tree construction (which the seed
//! design re-ran on every collective call) and of program compilation,
//! plus the plan-cache cold/warm comparison that justifies the
//! topology → plan → execute pipeline: a warm `PlanCache` hit skips the
//! tree build *and* the program compile entirely.
//!
//! Run: `cargo bench --bench tree_construction`

use gridcollect::benchkit::{section, Bench};
use gridcollect::collectives::programs;
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::plan::{AlgoPolicy, AllreduceAlgo, OpKind};
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::{build_strategy_tree, LevelPolicy, Strategy, TreeShape};

fn main() {
    let bench = Bench::default();

    section("tree construction wall-clock");
    for (sites, machines, procs) in [(2usize, 2usize, 12usize), (8, 4, 8), (16, 8, 8)] {
        let spec = TopologySpec::uniform(sites, machines, procs).unwrap();
        let comm = Communicator::world(&spec);
        let n = comm.size();
        for s in Strategy::ALL {
            bench.run(&format!("build/{}x{}x{} (n={n})/{}", sites, machines, procs, s.name()), || {
                let t =
                    build_strategy_tree(&comm, 0, s, &LevelPolicy::paper()).unwrap();
                std::hint::black_box(t.n_members());
            });
        }
    }

    section("single-shape builders (1024 ranks)");
    let ids: Vec<usize> = (0..1024).collect();
    for shape in
        [TreeShape::Binomial, TreeShape::Flat, TreeShape::Chain, TreeShape::Fibonacci(3)]
    {
        bench.run(&format!("shape/{}/1024", shape.name()), || {
            let t = shape.build(1024, &ids, 0).unwrap();
            std::hint::black_box(t.n_members());
        });
    }

    section("program compilation (tree -> simulator IR), 512 ranks");
    let spec = TopologySpec::uniform(8, 8, 8).unwrap();
    let comm = Communicator::world(&spec);
    let tree = build_strategy_tree(&comm, 0, Strategy::Multilevel, &LevelPolicy::paper()).unwrap();
    bench.run("program/bcast/512", || {
        std::hint::black_box(programs::bcast(&tree, 1).unwrap().total_actions());
    });
    bench.run("program/reduce/512", || {
        std::hint::black_box(programs::reduce(&tree, ReduceOp::Sum, 1).unwrap().total_actions());
    });
    bench.run("program/scatter/512", || {
        std::hint::black_box(programs::scatter(&tree, 1).unwrap().total_actions());
    });

    section("plan cache: cold build vs warm hit (paper grid, 48 ranks)");
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let params = presets::paper_grid();
    let ops = [
        OpKind::Bcast,
        OpKind::Reduce(ReduceOp::Sum),
        OpKind::Allreduce(ReduceOp::Sum, AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast)),
        OpKind::Allreduce(
            ReduceOp::Sum,
            AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
        ),
        OpKind::Allreduce(ReduceOp::Sum, AlgoPolicy::hybrid(1)),
    ];
    for op in ops {
        let label = match op {
            OpKind::Allreduce(_, policy) => format!("{}[{}]", op.name(), policy.name()),
            _ => op.name().to_string(),
        };
        // Cold: a fresh session (own cache) every iteration — tree build
        // + compile + meta.
        bench.run(&format!("plan/cold/{label}"), || {
            let session = GridSession::new(&comm, params.clone(), Strategy::Multilevel);
            let plan = session.plan_for(0, op, 1).unwrap();
            std::hint::black_box(plan.meta.total_messages());
        });
        // Warm: the plan was built once; every call is a pure lookup.
        let session = GridSession::new(&comm, params.clone(), Strategy::Multilevel);
        session.plan_for(0, op, 1).unwrap();
        bench.run(&format!("plan/warm/{label}"), || {
            let plan = session.plan_for(0, op, 1).unwrap();
            std::hint::black_box(plan.meta.total_messages());
        });
    }

    section("plan cache: 512 ranks, warm amortization");
    let big = Communicator::world(&TopologySpec::uniform(8, 8, 8).unwrap());
    let big_op = OpKind::Allreduce(ReduceOp::Sum, AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast));
    bench.run("plan/cold/allreduce/512", || {
        let session = GridSession::new(&big, params.clone(), Strategy::Multilevel);
        std::hint::black_box(session.plan_for(0, big_op, 1).unwrap().meta.total_messages());
    });
    let session = GridSession::new(&big, params.clone(), Strategy::Multilevel);
    session.plan_for(0, big_op, 1).unwrap();
    bench.run("plan/warm/allreduce/512", || {
        std::hint::black_box(session.plan_for(0, big_op, 1).unwrap().meta.total_messages());
    });
}
