//! Bench E12a — wall-clock cost of tree construction (the §3.2 design
//! requires every rank to rebuild the tree at each collective call, so
//! construction is on the L3 hot path) and of program compilation.
//!
//! Run: `cargo bench --bench tree_construction`

use gridcollect::benchkit::{section, Bench};
use gridcollect::collectives::programs;
use gridcollect::netsim::ReduceOp;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::{build_strategy_tree, LevelPolicy, Strategy, TreeShape};

fn main() {
    let bench = Bench::default();

    section("tree construction wall-clock");
    for (sites, machines, procs) in [(2usize, 2usize, 12usize), (8, 4, 8), (16, 8, 8)] {
        let spec = TopologySpec::uniform(sites, machines, procs).unwrap();
        let comm = Communicator::world(&spec);
        let n = comm.size();
        for s in Strategy::ALL {
            bench.run(&format!("build/{}x{}x{} (n={n})/{}", sites, machines, procs, s.name()), || {
                let t =
                    build_strategy_tree(&comm, 0, s, &LevelPolicy::paper()).unwrap();
                std::hint::black_box(t.n_members());
            });
        }
    }

    section("single-shape builders (1024 ranks)");
    let ids: Vec<usize> = (0..1024).collect();
    for shape in
        [TreeShape::Binomial, TreeShape::Flat, TreeShape::Chain, TreeShape::Fibonacci(3)]
    {
        bench.run(&format!("shape/{}/1024", shape.name()), || {
            let t = shape.build(1024, &ids, 0).unwrap();
            std::hint::black_box(t.n_members());
        });
    }

    section("program compilation (tree -> simulator IR), 512 ranks");
    let spec = TopologySpec::uniform(8, 8, 8).unwrap();
    let comm = Communicator::world(&spec);
    let tree = build_strategy_tree(&comm, 0, Strategy::Multilevel, &LevelPolicy::paper()).unwrap();
    bench.run("program/bcast/512", || {
        std::hint::black_box(programs::bcast(&tree, 1).unwrap().total_actions());
    });
    bench.run("program/reduce/512", || {
        std::hint::black_box(programs::reduce(&tree, ReduceOp::Sum, 1).unwrap().total_actions());
    });
    bench.run("program/scatter/512", || {
        std::hint::black_box(programs::scatter(&tree, 1).unwrap().total_actions());
    });
}
