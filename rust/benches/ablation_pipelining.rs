//! Bench E9d — van de Geijn segmentation/pipelining ablation (§5/§6):
//! broadcast time vs segment count across message sizes, the PLogP-style
//! tuned optimum, and segmentation composed with each strategy.
//!
//! Run: `cargo bench --bench ablation_pipelining`

use gridcollect::benchkit::{save_report, section};
use gridcollect::coordinator::experiment;
use gridcollect::session::GridSession;
use gridcollect::tree::Strategy;
use gridcollect::util::fmt::{self, Table};

fn main() {
    let comm = experiment::paper_comm();
    let params = experiment::paper_params();

    section("E9d — segment-count sweep (multilevel bcast, paper grid)");
    // One session across all sizes: plans are payload-size-independent,
    // so every size after the first runs entirely warm.
    let session = GridSession::new(&comm, params.clone(), Strategy::Multilevel);
    let mut t = Table::new(&["msg size", "S=1", "S=4", "S=16", "S=64", "tuned S", "tuned time"]);
    for bytes in [16384usize, 262144, 1 << 20, 4 << 20] {
        let data = vec![0.5f32; bytes / 4];
        let at = |s: usize| session.bcast_segmented(0, &data, s).unwrap().sim.makespan_us;
        let (best_s, best_us) =
            session.tune_bcast_segments(0, &data, &[1, 2, 4, 8, 16, 32, 64, 128]).unwrap();
        t.row(&[
            fmt::bytes(bytes),
            fmt::time_us(at(1)),
            fmt::time_us(at(4)),
            fmt::time_us(at(16)),
            fmt::time_us(at(64)),
            best_s.to_string(),
            fmt::time_us(best_us),
        ]);
    }
    print!("{}", t.to_markdown());
    save_report("pipelining_sweep", &t);

    section("E9d' — tuned segment-count table (ghost probes, persistable)");
    // The same sweep as a provenance-stamped PolicyTable: ghost probes,
    // zero payload allocation, consumable via bcast_segmented_auto.
    let sizes = [16384usize, 262144, 1 << 20, 4 << 20];
    let (table, policy_table) =
        session.tune_bcast_table(0, &sizes, &[1, 2, 4, 8, 16, 32, 64, 128]).unwrap();
    print!("{}", table.to_markdown());
    assert_eq!(policy_table.bcast_segment_entries().len(), sizes.len());
    save_report("pipelining_tuned_table", &table);

    section("E9e — segmentation x strategy (1 MiB)");
    let data = vec![0.5f32; (1 << 20) / 4];
    let mut t = Table::new(&["strategy", "plain", "tuned segmented", "gain"]);
    for s in Strategy::ALL {
        let session = GridSession::new(&comm, params.clone(), s);
        let plain = session.bcast(0, &data).unwrap().sim.makespan_us;
        let (_, tuned) = session.tune_bcast_segments(0, &data, &[1, 4, 16, 64]).unwrap();
        t.row(&[
            s.name().to_string(),
            fmt::time_us(plain),
            fmt::time_us(tuned),
            format!("{:.2}x", plain / tuned),
        ]);
    }
    print!("{}", t.to_markdown());
    save_report("pipelining_by_strategy", &t);

    section("E9f — PLogP-style parameter fitting (model::fit)");
    use gridcollect::model::fit;
    let c = gridcollect::topology::TopologySpec::paper_fig1().clustering();
    let fitted =
        fit::calibrate(&c, &params, &[1024, 8192, 65536, 524288]).unwrap();
    let mut t = Table::new(&["sep level", "fitted const (lat+o)", "fitted bandwidth", "true bandwidth"]);
    for (sep, l) in fitted {
        let truth = params.at_sep(sep);
        t.row(&[
            gridcollect::model::sep_name(sep, c.n_levels()).to_string(),
            fmt::time_us(l.latency_us),
            format!("{:.2} MB/s", l.bandwidth_mb_s),
            format!("{:.2} MB/s", truth.bandwidth_mb_s),
        ]);
    }
    print!("{}", t.to_markdown());
    save_report("plogp_fit", &t);
}
