//! Bench: engine throughput — full vs ghost execution on the
//! ready-queue core, with the retained rescan scheduler as the third
//! point of comparison (full-rescan vs full vs ghost).
//!
//! The workload is the Fig. 8 sweep point: one fused simulation of the
//! whole Fig. 7 rotation (n broadcasts + n ack-barriers) against the
//! engine's memoized rotation schedule. Each measured case is the
//! complete per-point cost — initial-register construction plus one
//! engine run — exactly what `timing_app::run_point_with` pays per
//! sweep point in each mode. A second workload measures the boundary
//! tuner (`tune_allreduce_boundary`), whose warm sweep is the
//! ghost engine's payoff path.
//!
//! Reported per case: wall time and actions/sec (retired program
//! actions per second of engine wall time). The summary table records
//! the ghost-vs-full speedup per payload size — the perf-trajectory
//! number the ISSUE 4 acceptance tracks.
//!
//! Run: `cargo bench --bench engine_throughput`
//! Smoke (CI): `cargo bench --bench engine_throughput -- --smoke`
//! Reports land in `target/bench-reports/` (md/csv + BENCH_*.json).

use gridcollect::benchkit::{save_bench_json, save_report, section, Bench, BenchResult};
use gridcollect::collectives::{request, CollectiveEngine};
use gridcollect::coordinator::{rotation_schedule_memo, tuning};
use gridcollect::netsim::{
    testing::run_rescan, ExecMode, GhostPayload, NativeCombiner, Payload, ReduceOp, SimConfig,
    SimResult,
};
use gridcollect::plan::{AlgoPolicy, AllreduceAlgo, OpKind};
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::fmt::{self, Table};
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let bench = if smoke {
        // 1 sample: CI smoke mode only checks the harness runs end to end.
        Bench { warmup_iters: 0, min_iters: 1, max_iters: 1, target: Duration::ZERO }
    } else {
        Bench::default()
    };
    let sizes: Vec<usize> = if smoke { vec![65536] } else { vec![4096, 65536, 1 << 20] };

    let comm = gridcollect::coordinator::experiment::paper_comm();
    let params = gridcollect::coordinator::experiment::paper_params();
    let n = comm.size();
    let mut results: Vec<BenchResult> = Vec::new();

    section("fig8 sweep point, warm engine — full-rescan vs full vs ghost");
    let session = GridSession::new(&comm, params.clone(), Strategy::Multilevel);
    let schedule = rotation_schedule_memo(&session).unwrap();
    let actions = schedule.program().total_actions();
    let rescan_cfg = SimConfig::new(params.clone());
    let mut summary = Table::new(&[
        "msg size", "rescan-full", "full", "ghost", "ghost vs full", "ghost actions/s",
    ]);
    for &bytes in &sizes {
        let elems = bytes / 4;
        let label = fmt::bytes(bytes);
        let rescan = bench.run(&format!("point/warm/rescan-full/{label}"), || {
            let mut init = vec![Payload::empty(); n];
            init[0] = Payload::single(0, vec![1.0f32; elems]);
            let c = comm.clustering();
            let prog = schedule.program();
            let sim = run_rescan(c, prog, init, &rescan_cfg, &NativeCombiner).unwrap();
            std::hint::black_box(sim.makespan_us);
        });
        let full = bench.run(&format!("point/warm/full/{label}"), || {
            let mut init = vec![Payload::empty(); n];
            init[0] = Payload::single(0, vec![1.0f32; elems]);
            let sim = session.run_schedule(&schedule, init).unwrap();
            std::hint::black_box(sim.makespan_us);
        });
        let ghost = bench.run(&format!("point/warm/ghost/{label}"), || {
            let mut init = vec![GhostPayload::empty(); n];
            init[0] = GhostPayload::single(0, elems);
            let sim = session.run_schedule_timing(&schedule, init).unwrap();
            std::hint::black_box(sim.makespan_us);
        });
        let speedup = full.median_us / ghost.median_us.max(1e-9);
        let actions_per_sec = actions as f64 / (ghost.median_us.max(1e-9) / 1e6);
        summary.row(&[
            label,
            fmt::time_us(rescan.median_us),
            fmt::time_us(full.median_us),
            fmt::time_us(ghost.median_us),
            format!("{speedup:.2}x"),
            format!("{actions_per_sec:.0}"),
        ]);
        results.push(rescan);
        results.push(full);
        results.push(ghost);
    }
    print!("{}", summary.to_markdown());
    save_report("engine_throughput_summary", &summary);

    section("fig8 sweep point, cold engine — plan builds + schedule assembly included");
    for &bytes in &sizes {
        let label = fmt::bytes(bytes);
        results.push(bench.run(&format!("point/cold/ghost/{label}"), || {
            let s = GridSession::new(&comm, params.clone(), Strategy::Multilevel);
            let p = gridcollect::coordinator::run_point_with(&s, bytes).unwrap();
            std::hint::black_box(p.total_us);
        }));
    }

    section("boundary tuner — full candidate sweep per call");
    let tuned = CollectiveEngine::new(&comm, params.clone(), Strategy::Multilevel);
    tuning::tune_allreduce_boundary(&tuned, ReduceOp::Sum, sizes[0]).unwrap(); // prime plans
    for &bytes in &sizes {
        let label = fmt::bytes(bytes);
        results.push(bench.run(&format!("tune/warm/{label}"), || {
            let t = tuning::tune_allreduce_boundary(&tuned, ReduceOp::Sum, bytes).unwrap();
            std::hint::black_box(t.best_us);
        }));
        results.push(bench.run(&format!("tune/cold/{label}"), || {
            let e = CollectiveEngine::new(&comm, params.clone(), Strategy::Multilevel);
            let t = tuning::tune_allreduce_boundary(&e, ReduceOp::Sum, bytes).unwrap();
            std::hint::black_box(t.best_us);
        }));
    }

    section("shard scaling — sharded ghost allreduce, 100,000 ranks / 8 sites");
    // The hierarchical shard tree's scaling curve: 8 sites x 25 machines
    // x 500 procs = 100,000 ranks, measured at 1/2/4/8/16 threads. The
    // tree recurses below the site level, so thread counts past the site
    // count still find independent shards; BENCH_shard_scaling.json
    // carries the whole curve as the perf-trajectory record.
    let big = Communicator::world(&TopologySpec::uniform(8, 25, 500).unwrap());
    let policy = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast);
    let elems = 65536 / 4;
    let probe = request::AllreduceProbe { root: 0, op: ReduceOp::Sum, policy, elems };
    let big_actions = {
        let s = GridSession::new(&big, params.clone(), Strategy::Multilevel);
        s.plan_for(0, OpKind::Allreduce(ReduceOp::Sum, policy), 1).unwrap().program.total_actions()
    };
    let mut scaling = Table::new(&["threads", "median", "actions/s", "vs sequential"]);
    let mut scaling_results: Vec<BenchResult> = Vec::new();
    let mut seq_us = f64::NAN;
    for threads in [1usize, 2, 4, 8, 16] {
        let mode = if threads > 1 { ExecMode::Sharded { threads } } else { ExecMode::Sequential };
        let s = GridSession::new(&big, params.clone(), Strategy::Multilevel).with_exec_mode(mode);
        let mut sim = SimResult::default();
        s.simulate_timing_into(&probe, &mut sim).unwrap(); // prime plan + shard arenas
        let r = bench.run(&format!("shard/ghost-allreduce/{}", mode.name()), || {
            s.simulate_timing_into(&probe, &mut sim).unwrap();
            std::hint::black_box(sim.makespan_us);
        });
        if threads == 1 {
            seq_us = r.median_us;
        }
        let actions_per_sec = big_actions as f64 / (r.median_us.max(1e-9) / 1e6);
        scaling.row(&[
            threads.to_string(),
            fmt::time_us(r.median_us),
            format!("{actions_per_sec:.0}"),
            format!("{:.2}x", seq_us / r.median_us.max(1e-9)),
        ]);
        scaling_results.push(r);
    }
    print!("{}", scaling.to_markdown());
    save_report("shard_scaling_summary", &scaling);
    save_bench_json("shard_scaling", &scaling_results);

    let mut wall = Table::new(&["case", "median us", "mean us", "iters"]);
    for r in &results {
        wall.row(&[
            r.name.clone(),
            format!("{:.1}", r.median_us),
            format!("{:.1}", r.mean_us),
            r.iters.to_string(),
        ]);
    }
    save_report("engine_throughput_wall", &wall);
    save_bench_json("engine_throughput", &results);
}
