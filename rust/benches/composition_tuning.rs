//! Bench E15 — per-level composition autotuning: beam-vs-oracle probe
//! economy by clustering depth, the tuned-composition table on the
//! paper grid, and sweep wall-clock cold (fresh engine) vs warm
//! (long-lived plan cache).
//!
//! Run: `cargo bench --bench composition_tuning`
//! Smoke (CI): `cargo bench --bench composition_tuning -- --smoke`
//! Reports land in `target/bench-reports/` (md/csv + BENCH_*.json).

use gridcollect::benchkit::{save_bench_json, save_report, section, Bench};
use gridcollect::collectives::CollectiveEngine;
use gridcollect::coordinator::tuning::{
    composition_tuning_table, tune_allreduce_composition, CompositionTuning, SearchMode,
    DEFAULT_BEAM_WIDTH,
};
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::topology::{Communicator, GroupNode, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::fmt::Table;
use std::time::Duration;

/// 24 ranks over 4 separation levels (machine / LAN / site / WAN): the
/// smallest topology where `SearchMode::Auto` resolves to beam search.
fn deep_comm() -> Communicator {
    let spec = TopologySpec::new(
        "deep",
        GroupNode::group(
            "grid",
            (0..2)
                .map(|s| {
                    GroupNode::group(
                        format!("site{s}"),
                        (0..2)
                            .map(|l| {
                                GroupNode::group(
                                    format!("s{s}lan{l}"),
                                    (0..2)
                                        .map(|m| GroupNode::machine(format!("s{s}l{l}m{m}"), 3))
                                        .collect(),
                                )
                            })
                            .collect(),
                    )
                })
                .collect(),
        ),
    )
    .unwrap();
    Communicator::world(&spec)
}

/// Sum-allreduce composition sweep at the bench's fixed 64 KiB point.
fn tune(e: &CollectiveEngine, mode: SearchMode) -> CompositionTuning {
    tune_allreduce_composition(e, ReduceOp::Sum, 65536, mode).unwrap()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let bench = if smoke {
        // 1 sample: CI smoke mode only checks the harness runs end to end.
        Bench { warmup_iters: 0, min_iters: 1, max_iters: 1, target: Duration::ZERO }
    } else {
        Bench::quick()
    };
    let sizes: Vec<usize> = if smoke { vec![65536] } else { vec![4096, 65536, 1 << 20] };

    section("E15a — probe economy by clustering depth (64 KiB allreduce)");
    let cases = [
        ("paper_fig1", Communicator::world(&TopologySpec::paper_fig1()), presets::paper_grid()),
        (
            "paper_experiment",
            Communicator::world(&TopologySpec::paper_experiment()),
            presets::paper_grid(),
        ),
        ("deep-4level", deep_comm(), presets::deep_grid()),
    ];
    let mut economy = Table::new(&[
        "topology", "levels", "space", "beam probes", "oracle probes", "beam best", "oracle best",
    ]);
    for (name, comm, params) in &cases {
        let e = CollectiveEngine::new(comm, params.clone(), Strategy::Multilevel);
        let beam = tune(&e, SearchMode::Beam { width: DEFAULT_BEAM_WIDTH });
        let ex = tune(&e, SearchMode::Exhaustive);
        if comm.clustering().n_levels() <= 3 {
            // The differential-oracle contract, re-checked in bench context.
            assert_eq!(beam.best, ex.best, "{name}: beam argmin == exhaustive argmin");
        }
        economy.row(&[
            (*name).to_string(),
            comm.clustering().n_levels().to_string(),
            ex.exhaustive_space.to_string(),
            beam.probes_issued.to_string(),
            ex.probes_issued.to_string(),
            beam.best.name(),
            ex.best.name(),
        ]);
    }
    print!("{}", economy.to_markdown());
    save_report("composition_probe_economy", &economy);

    section("E15b — tuned composition table (paper grid, ghost probes)");
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let engine = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let (table, tunings) =
        composition_tuning_table(&engine, ReduceOp::Sum, &sizes, SearchMode::Auto).unwrap();
    print!("{}", table.to_markdown());
    assert_eq!(tunings.len(), sizes.len());
    save_report("composition_tuned_table", &table);

    section("E15c — sweep wall-clock: cold engine vs warm plan cache (64 KiB)");
    let mut results = Vec::new();
    tune(&engine, SearchMode::Exhaustive);
    results.push(bench.run("sweep/warm/paper/exhaustive", || {
        let t = tune(&engine, SearchMode::Exhaustive);
        std::hint::black_box(t.best_us);
    }));

    let deep = deep_comm();
    let warm = CollectiveEngine::new(&deep, presets::deep_grid(), Strategy::Multilevel);
    tune(&warm, SearchMode::Auto);
    results.push(bench.run("sweep/warm/deep/beam", || {
        let t = tune(&warm, SearchMode::Auto);
        std::hint::black_box(t.best_us);
    }));
    results.push(bench.run("sweep/warm/deep/exhaustive", || {
        let t = tune(&warm, SearchMode::Exhaustive);
        std::hint::black_box(t.best_us);
    }));
    results.push(bench.run("sweep/cold/deep/beam", || {
        let e = CollectiveEngine::new(&deep, presets::deep_grid(), Strategy::Multilevel);
        let t = tune(&e, SearchMode::Auto);
        std::hint::black_box(t.best_us);
    }));

    let mut wall = Table::new(&["case", "median us", "mean us", "iters"]);
    for r in &results {
        wall.row(&[
            r.name.clone(),
            format!("{:.1}", r.median_us),
            format!("{:.1}", r.mean_us),
            r.iters.to_string(),
        ]);
    }
    save_report("composition_tuning_wall", &wall);
    save_bench_json("composition_tuning", &results);
}
