//! Bench E1 — regenerates Figure 8 (rotating-root broadcast, 4 strategies
//! × message sizes on the 48-process paper grid) and measures the
//! wall-clock cost of the simulation machinery itself (the L3 hot path).
//!
//! Run: `cargo bench --bench fig8_bcast`

use gridcollect::benchkit::{save_report, section, Bench};
use gridcollect::coordinator::{experiment, timing_app};
use gridcollect::tree::Strategy;
use gridcollect::util::fmt;

fn main() {
    section("E1 / Figure 8 — virtual-time reproduction");
    let sizes = timing_app::default_sizes();
    let (table, pts) = experiment::fig8_table(&sizes).unwrap();
    print!("{}", table.to_markdown());
    save_report("fig8", &table);

    // Qualitative shape assertions (who wins, by how much).
    let at = |bytes: usize, s: Strategy| {
        pts.iter().find(|p| p.bytes == bytes && p.strategy == s).unwrap().total_us
    };
    let mut ok = true;
    for &b in &sizes {
        ok &= at(b, Strategy::Multilevel) <= at(b, Strategy::TwoLevelSite) + 1e-6;
        ok &= at(b, Strategy::TwoLevelSite) < at(b, Strategy::Unaware);
        ok &= at(b, Strategy::TwoLevelMachine) < at(b, Strategy::Unaware);
    }
    let b = 1 << 20;
    println!(
        "\nshape: multilevel vs binomial at {} = {:.2}x  [{}]",
        fmt::bytes(b),
        at(b, Strategy::Unaware) / at(b, Strategy::Multilevel),
        if ok { "OK" } else { "VIOLATED" }
    );

    // Wall-clock of the simulator machinery (L3 §Perf target).
    section("simulation machinery wall-clock (64 KiB bcast, 48 ranks)");
    let comm = experiment::paper_comm();
    let params = experiment::paper_params();
    let bench = Bench::default();
    for s in Strategy::ALL {
        let data = vec![1.0f32; 16384];
        let session = gridcollect::session::GridSession::new(&comm, params.clone(), s);
        bench.run(&format!("bcast/sim-wall/{}", s.name()), || {
            let out = session.bcast(0, &data).unwrap();
            std::hint::black_box(out.sim.makespan_us);
        });
    }

    section("full rotation wall-clock (Fig. 7 app, one size)");
    bench.run("fig7-rotation/multilevel/64KiB", || {
        let p = timing_app::run_point(&comm, &params, Strategy::Multilevel, 65536).unwrap();
        std::hint::black_box(p.total_us);
    });
}
