//! Bench E16 — topology discovery: inference wall-clock vs rank count.
//! Each case synthesizes a noiseless N×N cost matrix from a uniform
//! SxMxP ground truth and times `infer_clustering` (edge sort + two
//! Kruskal passes — the O(N² log N) front half of the pipeline), with
//! matrix synthesis timed separately. Recovery is asserted exact before
//! timing, so the bench doubles as a scale test.
//!
//! Run: `cargo bench --bench topology_discovery`
//! Smoke (CI): `cargo bench --bench topology_discovery -- --smoke`
//! Reports land in `target/bench-reports/` (md/csv + BENCH_*.json).

use gridcollect::benchkit::{save_bench_json, save_report, section, Bench};
use gridcollect::model::presets;
use gridcollect::topology::discover::{
    infer_clustering, synthesize_from_spec, DEFAULT_PROBE_BYTES,
};
use gridcollect::topology::TopologySpec;
use gridcollect::util::fmt::Table;
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let bench = if smoke {
        // 1 sample: CI smoke mode only checks the harness runs end to end.
        Bench { warmup_iters: 0, min_iters: 1, max_iters: 1, target: Duration::ZERO }
    } else {
        Bench::quick()
    };
    // 64 / 512 / 4096 ranks; smoke stays at 64 (the 4096-rank matrix
    // alone is ~16.7M entries).
    let grids: &[(usize, usize, usize)] = if smoke {
        &[(4, 4, 4)]
    } else {
        &[(4, 4, 4), (8, 8, 8), (16, 16, 16)]
    };

    section("E16 — discovery wall-clock vs rank count (noiseless uniform grids)");
    let mut results = Vec::new();
    let mut shape = Table::new(&["ranks", "levels", "clusters/level", "merge pts", "cuts"]);
    for &(s, machines, p) in grids {
        let spec = TopologySpec::uniform(s, machines, p).unwrap();
        let n = spec.n_procs();
        let m = synthesize_from_spec(&spec, &presets::paper_grid(), 0.0, 1);
        let d = infer_clustering(&m, DEFAULT_PROBE_BYTES).unwrap();
        assert_eq!(d.clustering, spec.clustering(), "{n} ranks: recovery must be exact");
        let per_level: Vec<String> = (0..d.clustering.n_levels())
            .map(|l| d.clustering.clusters_at(l).len().to_string())
            .collect();
        shape.row(&[
            n.to_string(),
            d.clustering.n_levels().to_string(),
            per_level.join("/"),
            d.merge_costs_us.len().to_string(),
            d.cut_costs_us.len().to_string(),
        ]);
        results.push(bench.run(&format!("synthesize/{n}"), || {
            let m = synthesize_from_spec(&spec, &presets::paper_grid(), 0.0, 1);
            std::hint::black_box(m.n_ranks());
        }));
        results.push(bench.run(&format!("infer/{n}"), || {
            let d = infer_clustering(&m, DEFAULT_PROBE_BYTES).unwrap();
            std::hint::black_box(d.clustering.n_levels());
        }));
    }
    print!("{}", shape.to_markdown());
    save_report("topology_discovery_shape", &shape);

    let mut wall = Table::new(&["case", "median us", "mean us", "iters"]);
    for r in &results {
        wall.row(&[
            r.name.clone(),
            format!("{:.1}", r.median_us),
            format!("{:.1}", r.mean_us),
            r.iters.to_string(),
        ]);
    }
    save_report("topology_discovery_wall", &wall);
    save_bench_json("topology_discovery", &results);
}
