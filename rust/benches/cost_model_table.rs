//! Bench E2 — regenerates the §4 analytic-vs-simulated cost comparison
//! across (P, C) and message-size regimes, and verifies the asymptotic
//! log2(C) saving in the latency-dominated regime.
//!
//! Run: `cargo bench --bench cost_model_table`

use gridcollect::benchkit::{save_report, section};
use gridcollect::coordinator::experiment;
use gridcollect::util::fmt;

fn main() {
    for bytes in [1024usize, 16384, 262144] {
        section(&format!("E2 — §4 model vs simulator at {}", fmt::bytes(bytes)));
        let t = experiment::cost_model_table(bytes).unwrap();
        print!("{}", t.to_markdown());
        save_report(&format!("cost_model_{bytes}"), &t);
    }

    section("asymptotic check (1 KiB, latency-dominated)");
    // In the latency-dominated regime the simulated speedup must approach
    // log2(C) from below; at 16 clusters it should exceed half of it.
    use gridcollect::analytic::TwoTier;
    use gridcollect::collectives::CollectiveEngine;
    use gridcollect::model::presets;
    use gridcollect::topology::{Communicator, TopologySpec};
    use gridcollect::tree::Strategy;
    let params = presets::paper_grid();
    let tt = TwoTier { slow: params.per_sep[0], fast: params.per_sep[2] };
    let mut all_ok = true;
    for (p, c) in [(32usize, 4usize), (64, 8), (128, 16)] {
        let comm = Communicator::world(&TopologySpec::uniform(c, 1, p / c).unwrap());
        let data = vec![0.0f32; 256];
        let b = CollectiveEngine::new(&comm, params.clone(), Strategy::Unaware)
            .bcast(0, &data)
            .unwrap()
            .sim
            .makespan_us;
        let m = CollectiveEngine::new(&comm, params.clone(), Strategy::Multilevel)
            .bcast(0, &data)
            .unwrap()
            .sim
            .makespan_us;
        let speedup = b / m;
        let bound = tt.asymptotic_speedup(c);
        let ok = speedup > bound * 0.5 && speedup <= bound * 1.05;
        all_ok &= ok;
        println!(
            "P={p:<4} C={c:<3} speedup {speedup:.2}x vs log2(C)={bound:.2}  [{}]",
            if ok { "OK" } else { "OUT OF BAND" }
        );
    }
    println!("asymptotic shape: {}", if all_ok { "OK" } else { "VIOLATED" });
}
