//! Bench E10 — scaling studies: site-count scaling at fixed process
//! count, root-placement sensitivity (the binomial tree is "acutely
//! sensitive to the distribution of the processes and the root" — §4),
//! and depth scaling on the 4-level topology.
//!
//! Run: `cargo bench --bench scaling_sites`

use gridcollect::benchkit::{save_report, section};
use gridcollect::coordinator::experiment;
use gridcollect::model::presets;
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, GroupNode, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::fmt::{self, Table};

fn main() {
    section("E10a — site-count scaling (64 procs, 64 KiB)");
    let t = experiment::site_scaling_table(65536).unwrap();
    print!("{}", t.to_markdown());
    save_report("scaling_sites", &t);

    section("E10b — root sensitivity (paper grid, 64 KiB)");
    let t = experiment::root_sensitivity_table(65536).unwrap();
    print!("{}", t.to_markdown());
    save_report("root_sensitivity", &t);

    section("E10c — hierarchy depth: 3-level vs 4-level clustering");
    // Same 24 processes; once as 2 sites x 2 machines x 6, once as
    // 2 sites x 2 LANs x 2 machines x 3 with a campus tier between.
    // Deliberately NOT power-of-two per level: with aligned blocks the
    // binomial tree is accidentally hierarchical and everything ties.
    let three = TopologySpec::uniform(2, 2, 6).unwrap();
    let four = TopologySpec::new(
        "deep",
        GroupNode::group(
            "grid",
            (0..2)
                .map(|s| {
                    GroupNode::group(
                        format!("site{s}"),
                        (0..2)
                            .map(|l| {
                                GroupNode::group(
                                    format!("s{s}lan{l}"),
                                    (0..2)
                                        .map(|m| {
                                            GroupNode::machine(format!("s{s}l{l}m{m}"), 3)
                                        })
                                        .collect(),
                                )
                            })
                            .collect(),
                    )
                })
                .collect(),
        ),
    )
    .unwrap();
    let mut t = Table::new(&["topology", "strategy", "makespan", "WAN msgs", "msgs by level"]);
    let data = vec![0.5f32; 16384];
    // rotation-summed over all roots (Fig. 7 methodology)
    let rotation = |comm: &Communicator,
                    params: &gridcollect::model::NetworkParams,
                    s: Strategy,
                    data: &[f32]|
     -> (f64, u64) {
        let session = GridSession::new(comm, params.clone(), s);
        let mut us = 0.0;
        let mut wan = 0;
        for root in 0..comm.size() {
            let out = session.bcast(root, data).unwrap();
            us += out.sim.makespan_us;
            wan += out.sim.wan_messages();
        }
        (us, wan)
    };
    for (name, spec, params) in [
        ("3-level", &three, presets::paper_grid()),
        ("4-level", &four, presets::deep_grid()),
    ] {
        let comm = Communicator::world(spec);
        for s in [Strategy::Unaware, Strategy::TwoLevelSite, Strategy::Multilevel] {
            let (us, wan) = rotation(&comm, &params, s, &data);
            let one = GridSession::new(&comm, params.clone(), s).bcast(0, &data).unwrap();
            t.row(&[
                name.to_string(),
                s.name().to_string(),
                fmt::time_us(us),
                wan.to_string(),
                format!("{:?}", one.sim.msgs_by_sep),
            ]);
        }
    }
    print!("{}", t.to_markdown());
    save_report("scaling_depth", &t);

    section("E10d — the deeper hierarchy pays: 4-level multilevel vs 2-level view");
    // On the 4-level topology, compare full multilevel against the best
    // 2-level approximation (site view) as message size grows
    // (rotation-summed over all roots).
    let comm = Communicator::world(&four);
    let params = presets::deep_grid();
    let mut t = Table::new(&["msg size", "2-level (site)", "multilevel (4-level)", "gain"]);
    for bytes in [4096usize, 65536, 1 << 20] {
        let data = vec![0.5f32; bytes / 4];
        let (two, _) = rotation(&comm, &params, Strategy::TwoLevelSite, &data);
        let (multi, _) = rotation(&comm, &params, Strategy::Multilevel, &data);
        t.row(&[
            fmt::bytes(bytes),
            fmt::time_us(two),
            fmt::time_us(multi),
            format!("{:.2}x", two / multi),
        ]);
    }
    print!("{}", t.to_markdown());
    save_report("scaling_depth_gain", &t);

    section("E10e — machines per site: where multilevel beats 2-level-site");
    // With many machines per site, the site-level binomial (machine-
    // unaware) chains LAN transfers on the critical path; the multilevel
    // tree crosses the LAN once per machine with intra-machine fan-out.
    let mut t = Table::new(&["machines/site", "2-level (site)", "multilevel", "gain"]);
    for machines in [2usize, 4, 8] {
        let spec = TopologySpec::uniform(2, machines, 24 / machines).unwrap();
        let comm = Communicator::world(&spec);
        let params = presets::paper_grid();
        let data = vec![0.5f32; 65536 / 4];
        let (two, _) = rotation(&comm, &params, Strategy::TwoLevelSite, &data);
        let (multi, _) = rotation(&comm, &params, Strategy::Multilevel, &data);
        t.row(&[
            machines.to_string(),
            fmt::time_us(two),
            fmt::time_us(multi),
            format!("{:.2}x", two / multi),
        ]);
    }
    print!("{}", t.to_markdown());
    save_report("machines_per_site", &t);
}
