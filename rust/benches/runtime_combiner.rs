//! Bench E12b — the L1/L2 compute hot path: PJRT combiner throughput
//! (the AOT-compiled Pallas combine kernels) vs the native Rust combiner,
//! across payload sizes, plus the MLP train-step latency. Prints the
//! calibrated `combine_us_per_byte` for the simulator.
//!
//! Skips (with a notice) when `make artifacts` has not been run.
//!
//! Run: `cargo bench --bench runtime_combiner`

use gridcollect::benchkit::{section, Bench};
use gridcollect::netsim::{Combiner, NativeCombiner, ReduceOp};
use gridcollect::runtime::{artifacts::default_dir, calibrate_us_per_byte, MlpRuntime, Runtime, XlaCombiner};
use gridcollect::util::fmt;

fn main() {
    let dir = default_dir();
    if !dir.join("manifest.tsv").is_file() {
        println!("artifacts not built (run `make artifacts`); skipping runtime benches");
        return;
    }
    let rt = Runtime::open(dir).unwrap();
    println!("PJRT platform: {}", rt.platform());
    let xla = XlaCombiner::open_default(&rt).unwrap();
    let native = NativeCombiner;
    let bench = Bench::default();

    section("combine throughput: PJRT(Pallas AOT) vs native Rust");
    for elems in [16384usize, 65536, 262144] {
        let bytes = elems * 4;
        let src: Vec<f32> = (0..elems).map(|i| (i % 97) as f32).collect();
        let mut acc_a = vec![1.0f32; elems];
        let r = bench.run(&format!("combine/xla/{}", fmt::bytes(bytes)), || {
            xla.combine(ReduceOp::Sum, &mut acc_a, &src);
        });
        println!("    -> {}", fmt::rate(bytes, r.median_us));
        let mut acc_b = vec![1.0f32; elems];
        let r = bench.run(&format!("combine/native/{}", fmt::bytes(bytes)), || {
            native.combine(ReduceOp::Sum, &mut acc_b, &src);
        });
        println!("    -> {}", fmt::rate(bytes, r.median_us));
    }

    section("per-op PJRT combine (64 KiB)");
    let elems = 16384;
    let src: Vec<f32> = (0..elems).map(|i| 1.0 + (i % 7) as f32 * 0.1).collect();
    for op in ReduceOp::ALL {
        let mut acc = vec![1.0f32; elems];
        bench.run(&format!("combine/xla/{}", op.name()), || {
            acc.iter_mut().for_each(|v| *v = 1.0); // keep prod bounded
            xla.combine(op, &mut acc, &src);
        });
    }

    section("calibration");
    let us_per_byte = calibrate_us_per_byte(&xla, 30);
    println!(
        "PJRT combine: {:.6} us/byte ({:.0} MB/s) — simulator default is 0.002 us/byte",
        us_per_byte,
        1.0 / us_per_byte
    );

    section("MLP train-step + sgd-step latency (L2 graphs via PJRT)");
    let mlp = MlpRuntime::open(&rt).unwrap();
    let p = mlp.init_params(0);
    let (x, y) = mlp.synth_batch(0);
    let mut grads = vec![0.0f32; mlp.dims.params];
    bench.run("mlp/train_step", || {
        let (g, loss) = mlp.train_step(&p, &x, &y).unwrap();
        grads.copy_from_slice(&g);
        std::hint::black_box(loss);
    });
    bench.run("mlp/sgd_step", || {
        std::hint::black_box(mlp.sgd_step(&p, &grads, 0.1).unwrap().len());
    });
}
