"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README).

Run once via ``make artifacts``; the Rust binary is self-contained
afterwards. Also writes ``artifacts/manifest.tsv`` describing every
artifact (whitespace-separated, trivially parseable without a JSON
library):

    name  file  kind  op  args...  in  <shapes>  out  <shapes>

Shapes are ``f32[AxB]``-style strings, comma-separated per argument.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

#: payload chunk length (f32 elements) for the combiner artifacts; the
#: Rust combiner pads/chunks arbitrary payloads to this size. 16384 f32
#: = 64 KiB per buffer = comfortably VMEM-resident at (8,128) tiling.
COMBINE_N = 16384
#: fused tree-node fan-in for the k-way combine artifact.
COMBINE_K = 8

OPS = ("sum", "max", "min", "prod")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_str(s) -> str:
    return f"f32[{'x'.join(str(d) for d in s.shape)}]"


def lower_entry(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    return to_hlo_text(lowered)


def build_artifacts():
    """Yield (name, kind, meta, fn, example_args, out_shapes)."""
    n = COMBINE_N
    f32 = jnp.float32
    for op in OPS:
        yield (
            f"combine2_{op}_{n}",
            "combine2",
            {"op": op, "n": n},
            model.combine2_fn(op, n),
            (jax.ShapeDtypeStruct((n,), f32), jax.ShapeDtypeStruct((n,), f32)),
            [(n,)],
        )
    yield (
        f"combine{COMBINE_K}_sum_{n}",
        "combine_k",
        {"op": "sum", "n": n, "k": COMBINE_K},
        model.combine_k_fn("sum", COMBINE_K, n),
        (jax.ShapeDtypeStruct((COMBINE_K, n), f32),),
        [(n,)],
    )
    p = model.mlp_padded_n()
    d_in, d_h, d_out = model.MLP_SIZES
    b = model.MLP_BATCH
    yield (
        "mlp_train_step",
        "train_step",
        {"params": p, "batch": b, "d_in": d_in, "d_h": d_h, "d_out": d_out},
        model.train_step_fn(),
        (
            jax.ShapeDtypeStruct((p,), f32),
            jax.ShapeDtypeStruct((b, d_in), f32),
            jax.ShapeDtypeStruct((b, d_out), f32),
        ),
        [(p,), ()],
    )
    yield (
        "mlp_sgd_step",
        "sgd_step",
        {"params": p},
        model.sgd_step_fn(),
        (
            jax.ShapeDtypeStruct((p,), f32),
            jax.ShapeDtypeStruct((p,), f32),
            jax.ShapeDtypeStruct((), f32),
        ),
        [(p,)],
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="only build artifacts whose name contains this")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, kind, meta, fn, example_args, out_shapes in build_artifacts():
        if args.only and args.only not in name:
            continue
        text = lower_entry(fn, example_args)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        ins = ",".join(_shape_str(s) for s in example_args)
        outs = ",".join(f"f32[{'x'.join(str(d) for d in s)}]" for s in out_shapes)
        meta_str = ";".join(f"{k}={v}" for k, v in sorted(meta.items()))
        manifest_lines.append(f"{name}\t{name}.hlo.txt\t{kind}\t{meta_str}\t{ins}\t{outs}")
        print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(args.out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("# name\tfile\tkind\tmeta\tinputs\toutputs\n")
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest} ({len(manifest_lines)} artifacts)")


if __name__ == "__main__":
    main()
