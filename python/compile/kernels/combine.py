"""L1 — Pallas reduction-combine kernels.

The compute hot-spot of the collective stack: the elementwise combine
executed at every interior node of an `MPI_Reduce` tree, plus the fused
k-way variant (one kernel invocation per tree node instead of k-1
accumulator re-reads) and the `axpy` SGD-update kernel used by the
training example.

TPU mapping (DESIGN.md §Hardware-Adaptation): buffers are viewed as
`(rows, 128)` — the VPU lane width — and tiled in `(block_rows, 128)`
VMEM blocks via `BlockSpec`. On CPU the kernels run under
``interpret=True`` (Mosaic custom-calls cannot execute on the CPU PJRT
plugin); the *structure* (one HBM pass, aligned tiles) is what carries
to real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128  # TPU VPU lane width; all kernels tile the last dim to this.

OPS = {
    "sum": jnp.add,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "prod": jnp.multiply,
}

REDUCERS = {
    "sum": jnp.sum,
    "max": jnp.max,
    "min": jnp.min,
    "prod": jnp.prod,
}


def _check_n(n: int, block_rows: int) -> int:
    """Validate n against the tiling; return the row count."""
    if n % LANE != 0:
        raise ValueError(f"n={n} must be a multiple of {LANE}")
    rows = n // LANE
    if rows % block_rows != 0:
        raise ValueError(f"rows={rows} must be a multiple of block_rows={block_rows}")
    return rows


def combine2(op: str, n: int, block_rows: int = 8):
    """Pairwise combine: f(x[n], y[n]) -> op(x, y) elementwise.

    Grid over row-blocks of a (rows, LANE) view; each block is combined
    entirely in VMEM.
    """
    fn = OPS[op]
    rows = _check_n(n, block_rows)

    def kernel(x_ref, y_ref, o_ref):
        o_ref[...] = fn(x_ref[...], y_ref[...])

    call = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=True,
    )

    def apply(x, y):
        x2 = x.reshape(rows, LANE)
        y2 = y.reshape(rows, LANE)
        return call(x2, y2).reshape(n)

    return apply


def combine_k(op: str, k: int, n: int, block_rows: int = 8):
    """Fused k-way combine: f(xs[k, n]) -> op over axis 0.

    One kernel invocation streams all k child buffers through VMEM once —
    the HBM analogue of the paper's minimize-slowest-channel rule.
    """
    if k < 1:
        raise ValueError("k must be >= 1")
    reducer = REDUCERS[op]
    rows = _check_n(n, block_rows)

    def kernel(x_ref, o_ref):
        o_ref[...] = reducer(x_ref[...], axis=0)

    call = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((k, block_rows, LANE), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=True,
    )

    def apply(xs):
        xs3 = xs.reshape(k, rows, LANE)
        return call(xs3).reshape(n)

    return apply


def axpy(n: int, block_rows: int = 8):
    """SGD update kernel: f(p[n], g[n], lr[1,1]) -> p - lr * g.

    `lr` arrives as a (1, 1) scalar block in SMEM-style placement.
    """
    rows = _check_n(n, block_rows)

    def kernel(p_ref, g_ref, lr_ref, o_ref):
        o_ref[...] = p_ref[...] - lr_ref[0, 0] * g_ref[...]

    call = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=True,
    )

    def apply(p, g, lr):
        p2 = p.reshape(rows, LANE)
        g2 = g.reshape(rows, LANE)
        lr2 = jnp.asarray(lr, jnp.float32).reshape(1, 1)
        return call(p2, g2, lr2).reshape(n)

    return apply


@functools.lru_cache(maxsize=None)
def combine2_jit(op: str, n: int, block_rows: int = 8):
    """Jitted, cached combine2 (used by tests and aot)."""
    return jax.jit(combine2(op, n, block_rows))


@functools.lru_cache(maxsize=None)
def combine_k_jit(op: str, k: int, n: int, block_rows: int = 8):
    return jax.jit(combine_k(op, k, n, block_rows))


@functools.lru_cache(maxsize=None)
def axpy_jit(n: int, block_rows: int = 8):
    return jax.jit(axpy(n, block_rows))
