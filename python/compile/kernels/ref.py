"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in `combine.py` is checked against these references by
`python/tests/test_kernels.py` (hypothesis sweeps shapes and operators);
this is the CORE correctness signal for the compute layer.
"""

from __future__ import annotations

import jax.numpy as jnp

OPS = {
    "sum": jnp.add,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "prod": jnp.multiply,
}

REDUCERS = {
    "sum": jnp.sum,
    "max": jnp.max,
    "min": jnp.min,
    "prod": jnp.prod,
}


def ref_combine2(op: str, x, y):
    """Elementwise op(x, y)."""
    return OPS[op](x, y)


def ref_combine_k(op: str, xs):
    """Reduce over axis 0 of xs[k, n]."""
    return REDUCERS[op](xs, axis=0)


def ref_axpy(p, g, lr):
    """p - lr * g."""
    return p - jnp.float32(lr) * g
