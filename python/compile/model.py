"""L2 — JAX compute graphs, AOT-lowered to HLO for the Rust runtime.

Three graph families, all calling the L1 Pallas kernels where the compute
is hot:

- ``combine2_fn`` / ``combine_k_fn`` — the MPI_Reduce payload combine
  (wraps `kernels.combine`), executed by `rust/src/runtime/combiner.rs`
  at every interior node of a reduction tree.
- ``train_step_fn`` — fwd+bwd+loss of the data-parallel MLP used by the
  end-to-end example (`examples/grid_training.rs`). Parameters travel as
  one flat, 128-aligned f32 vector so the Rust side can allreduce them
  with the combine kernels.
- ``sgd_step_fn`` — the parameter update, running the Pallas ``axpy``
  kernel.

Python never runs at request time: `aot.py` lowers these once into
`artifacts/*.hlo.txt`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import combine as K

# ----------------------------------------------------------------------
# Reduce-combine graphs (wrap L1 kernels 1:1)
# ----------------------------------------------------------------------


def combine2_fn(op: str, n: int, block_rows: int = 8):
    """(x[n], y[n]) -> (op(x, y),)"""
    k = K.combine2(op, n, block_rows)

    def fn(x, y):
        return (k(x, y),)

    return fn


def combine_k_fn(op: str, k: int, n: int, block_rows: int = 8):
    """(xs[k, n],) -> (op over axis 0,)"""
    kk = K.combine_k(op, k, n, block_rows)

    def fn(xs):
        return (kk(xs),)

    return fn


# ----------------------------------------------------------------------
# MLP for the end-to-end data-parallel training example
# ----------------------------------------------------------------------

#: (input dim, hidden dim, classes) — compact enough for CPU-interpret
#: execution, large enough that the allreduced gradient payload (~80 KiB)
#: exercises multi-chunk combining.
MLP_SIZES = (64, 256, 10)
MLP_BATCH = 32


def mlp_n_params(sizes=MLP_SIZES) -> int:
    d_in, d_h, d_out = sizes
    return d_in * d_h + d_h + d_h * d_out + d_out


def mlp_padded_n(sizes=MLP_SIZES) -> int:
    """Flat parameter vector length, padded to a multiple of 1024 so the
    Pallas kernels' (8, 128) tiling applies cleanly."""
    n = mlp_n_params(sizes)
    return (n + 1023) // 1024 * 1024


def _unflatten(flat, sizes=MLP_SIZES):
    d_in, d_h, d_out = sizes
    i = 0
    w1 = flat[i : i + d_in * d_h].reshape(d_in, d_h)
    i += d_in * d_h
    b1 = flat[i : i + d_h]
    i += d_h
    w2 = flat[i : i + d_h * d_out].reshape(d_h, d_out)
    i += d_h * d_out
    b2 = flat[i : i + d_out]
    return w1, b1, w2, b2


def mlp_loss(flat, x, y_onehot, sizes=MLP_SIZES):
    """Softmax cross-entropy of a 2-layer tanh MLP.

    flat: [padded_n] f32, x: [batch, d_in], y_onehot: [batch, d_out].
    """
    w1, b1, w2, b2 = _unflatten(flat, sizes)
    h = jnp.tanh(x @ w1 + b1)
    logits = h @ w2 + b2
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def train_step_fn(sizes=MLP_SIZES, batch=MLP_BATCH):
    """(flat[p], x[batch,d_in], y[batch,d_out]) -> (grads[p], loss[])"""
    padded = mlp_padded_n(sizes)

    def fn(flat, x, y_onehot):
        loss, grads = jax.value_and_grad(mlp_loss)(flat, x, y_onehot, sizes)
        # padding region has zero gradient by construction
        return grads.reshape(padded), loss

    return fn


def sgd_step_fn(sizes=MLP_SIZES, block_rows: int = 8):
    """(flat[p], grads[p], lr[]) -> (flat - lr*grads,) via the Pallas axpy."""
    padded = mlp_padded_n(sizes)
    ax = K.axpy(padded, block_rows)

    def fn(flat, grads, lr):
        return (ax(flat, grads, lr),)

    return fn


def mlp_init(seed: int, sizes=MLP_SIZES):
    """Glorot-ish init, returned as the padded flat vector (host-side
    convenience for tests; the Rust driver uses its own deterministic
    init with the same scheme)."""
    d_in, d_h, d_out = sizes
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (d_in, d_h), jnp.float32) * jnp.sqrt(2.0 / d_in)
    w2 = jax.random.normal(k2, (d_h, d_out), jnp.float32) * jnp.sqrt(2.0 / d_h)
    flat = jnp.concatenate(
        [w1.reshape(-1), jnp.zeros(d_h), w2.reshape(-1), jnp.zeros(d_out)]
    )
    pad = mlp_padded_n(sizes) - flat.shape[0]
    return jnp.concatenate([flat, jnp.zeros(pad)]).astype(jnp.float32)
