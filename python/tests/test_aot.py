"""AOT lowering smoke tests: every artifact lowers to parseable HLO text
with the expected entry computation signature."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_all_artifacts_lower():
    built = list(aot.build_artifacts())
    names = [b[0] for b in built]
    assert "combine2_sum_16384" in names
    assert "mlp_train_step" in names
    assert "mlp_sgd_step" in names
    assert f"combine{aot.COMBINE_K}_sum_{aot.COMBINE_N}" in names
    assert len(names) == len(set(names))


def test_combine2_hlo_text_structure():
    _, _, _, fn, args, _ = next(
        b for b in aot.build_artifacts() if b[0] == "combine2_sum_16384"
    )
    text = aot.lower_entry(fn, args)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # two f32[16384] params in some order
    assert text.count("f32[16384]") >= 3  # 2 inputs + output path
    # return_tuple=True: root is a tuple
    assert "(f32[16384]" in text


def test_train_step_hlo_has_expected_shapes():
    _, _, _, fn, args, _ = next(b for b in aot.build_artifacts() if b[0] == "mlp_train_step")
    text = aot.lower_entry(fn, args)
    p = model.mlp_padded_n()
    b, d_in = model.MLP_BATCH, model.MLP_SIZES[0]
    assert f"f32[{p}]" in text
    assert f"f32[{b},{d_in}]" in text


def test_lowered_combine_executes_same_as_eager():
    """Round-trip the stablehlo -> XlaComputation conversion and execute
    through jax's own client to make sure the converted module is valid."""
    from jax._src.lib import xla_client as xc

    n = 1024
    fn = model.combine2_fn("sum", n)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((n,), jnp.float32), jax.ShapeDtypeStruct((n,), jnp.float32)
    )
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text()
    assert "HloModule" in text and "f32[1024]" in text

    rng = np.random.default_rng(0)
    x = rng.normal(size=(n,)).astype(np.float32)
    y = rng.normal(size=(n,)).astype(np.float32)
    (eager,) = fn(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(eager), x + y, rtol=1e-6)


def test_manifest_shape_strings():
    for name, file, kind, meta, args, outs in [
        (b[0], f"{b[0]}.hlo.txt", b[1], b[2], b[4], b[5]) for b in aot.build_artifacts()
    ]:
        assert file.endswith(".hlo.txt")
        assert kind in ("combine2", "combine_k", "train_step", "sgd_step")
        for s in args:
            assert hasattr(s, "shape")
        assert isinstance(meta, dict) and meta
