"""L2 model correctness: shapes, gradients, training dynamics, and the
combine wrappers that the AOT artifacts lower."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def synth_batch(seed, batch=model.MLP_BATCH, sizes=model.MLP_SIZES):
    """Synthetic classification task: label = argmax of a fixed random
    linear projection of the input (learnable by the MLP)."""
    d_in, _, d_out = sizes
    rng = np.random.default_rng(seed)
    proj = np.random.default_rng(123).normal(size=(d_in, d_out))
    x = rng.normal(size=(batch, d_in)).astype(np.float32)
    labels = np.argmax(x @ proj, axis=1)
    y = np.eye(d_out, dtype=np.float32)[labels]
    return jnp.asarray(x), jnp.asarray(y)


def test_param_padding_is_lane_aligned():
    n = model.mlp_n_params()
    p = model.mlp_padded_n()
    assert p >= n
    assert p % 1024 == 0
    assert model.mlp_init(0).shape == (p,)


def test_train_step_shapes_and_finite():
    flat = model.mlp_init(0)
    x, y = synth_batch(0)
    grads, loss = model.train_step_fn()(flat, x, y)
    assert grads.shape == flat.shape
    assert loss.shape == ()
    assert np.isfinite(loss)
    assert np.all(np.isfinite(grads))
    # padding region must carry zero gradient
    n = model.mlp_n_params()
    np.testing.assert_array_equal(grads[n:], 0.0)


def test_initial_loss_near_uniform():
    flat = model.mlp_init(0)
    x, y = synth_batch(1)
    _, loss = model.train_step_fn()(flat, x, y)
    # log(10) ~ 2.30 for 10-way uniform predictions
    assert abs(float(loss) - np.log(10)) < 0.5


def test_sgd_training_reduces_loss():
    step = jax.jit(model.train_step_fn())
    sgd = jax.jit(model.sgd_step_fn())
    flat = model.mlp_init(0)
    losses = []
    for i in range(60):
        x, y = synth_batch(i % 8)
        grads, loss = step(flat, x, y)
        losses.append(float(loss))
        (flat,) = sgd(flat, grads, jnp.float32(0.1))
    assert losses[-1] < losses[0] * 0.5, f"no learning: {losses[0]:.3f} -> {losses[-1]:.3f}"


def test_sgd_step_matches_manual_update():
    flat = model.mlp_init(1)
    x, y = synth_batch(2)
    grads, _ = model.train_step_fn()(flat, x, y)
    (updated,) = model.sgd_step_fn()(flat, grads, jnp.float32(0.05))
    np.testing.assert_allclose(updated, flat - 0.05 * grads, rtol=1e-6, atol=1e-7)


def test_gradient_against_finite_differences():
    flat = model.mlp_init(3)
    x, y = synth_batch(3)
    grads, loss0 = model.train_step_fn()(flat, x, y)
    eps = 1e-3
    rng = np.random.default_rng(0)
    for idx in rng.integers(0, model.mlp_n_params(), size=5):
        bumped = flat.at[idx].add(eps)
        loss1 = model.mlp_loss(bumped, x, y)
        fd = (float(loss1) - float(loss0)) / eps
        assert abs(fd - float(grads[idx])) < 5e-2, f"idx {idx}: fd={fd} grad={grads[idx]}"


def test_combine_fns_wrap_kernels():
    n = 2048
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    (got,) = model.combine2_fn("max", n)(x, y)
    np.testing.assert_allclose(got, ref.ref_combine2("max", x, y), rtol=1e-6)
    xs = jnp.stack([x, y, x])
    (got_k,) = model.combine_k_fn("sum", 3, n)(xs)
    np.testing.assert_allclose(got_k, ref.ref_combine_k("sum", xs), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sizes", [(32, 64, 4), (64, 256, 10)])
def test_unflatten_roundtrip(sizes):
    n = model.mlp_n_params(sizes)
    flat = jnp.arange(model.mlp_padded_n(sizes), dtype=jnp.float32)
    w1, b1, w2, b2 = model._unflatten(flat, sizes)
    reflat = jnp.concatenate([w1.reshape(-1), b1, w2.reshape(-1), b2])
    np.testing.assert_array_equal(reflat, flat[:n])
