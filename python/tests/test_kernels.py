"""L1 kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle.

Hypothesis sweeps shapes (multiples of the tiling), operators, fan-ins and
value ranges; fixed cases pin the exact configurations the AOT artifacts
use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import combine as K
from compile.kernels import ref

OPS = ["sum", "max", "min", "prod"]

# shapes: n = rows * 128 with rows a multiple of block_rows
rows_strategy = st.sampled_from([8, 16, 24, 32, 64])
op_strategy = st.sampled_from(OPS)


def rand(shape, seed, lo=-4.0, hi=4.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, shape).astype(np.float32))


@settings(max_examples=40, deadline=None)
@given(rows=rows_strategy, op=op_strategy, seed=st.integers(0, 2**31 - 1))
def test_combine2_matches_ref(rows, op, seed):
    n = rows * K.LANE
    x = rand((n,), seed)
    y = rand((n,), seed + 1)
    got = K.combine2(op, n)(x, y)
    want = ref.ref_combine2(op, x, y)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    rows=st.sampled_from([8, 16, 32]),
    op=op_strategy,
    k=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_combine_k_matches_ref(rows, op, k, seed):
    n = rows * K.LANE
    # keep prod values near 1 to avoid over/underflow across k factors
    lo, hi = (0.5, 1.5) if op == "prod" else (-4.0, 4.0)
    xs = rand((k, n), seed, lo, hi)
    got = K.combine_k(op, k, n)(xs)
    want = ref.ref_combine_k(op, xs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    rows=rows_strategy,
    seed=st.integers(0, 2**31 - 1),
    lr=st.floats(1e-4, 1.0, allow_nan=False),
)
def test_axpy_matches_ref(rows, seed, lr):
    n = rows * K.LANE
    p = rand((n,), seed)
    g = rand((n,), seed + 7)
    got = K.axpy(n)(p, g, lr)
    want = ref.ref_axpy(p, g, lr)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("op", OPS)
def test_combine2_artifact_shape(op):
    """The exact configuration the AOT artifacts are built with."""
    n = 16384
    x = rand((n,), 1)
    y = rand((n,), 2)
    got = K.combine2_jit(op, n)(x, y)
    np.testing.assert_allclose(got, ref.ref_combine2(op, x, y), rtol=1e-6)


def test_combine_k_artifact_shape():
    n, k = 16384, 8
    xs = rand((k, n), 3)
    got = K.combine_k_jit("sum", k, n)(xs)
    np.testing.assert_allclose(got, ref.ref_combine_k("sum", xs), rtol=1e-5, atol=1e-5)


def test_block_rows_variants_agree():
    n = 4096
    x = rand((n,), 11)
    y = rand((n,), 12)
    base = K.combine2("sum", n, block_rows=8)(x, y)
    for br in [4, 16, 32]:
        other = K.combine2("sum", n, block_rows=br)(x, y)
        np.testing.assert_array_equal(base, other)


def test_invalid_shapes_rejected():
    with pytest.raises(ValueError):
        K.combine2("sum", 1000)  # not a multiple of 128
    with pytest.raises(ValueError):
        K.combine2("sum", 128 * 6, block_rows=4)  # rows=6 not divisible by 4
    with pytest.raises(ValueError):
        K.combine_k("sum", 0, 1024)
    with pytest.raises(KeyError):
        K.combine2("xor", 1024)


def test_special_values_propagate():
    n = 1024
    x = jnp.zeros((n,), jnp.float32).at[0].set(jnp.inf).at[1].set(-jnp.inf)
    y = jnp.ones((n,), jnp.float32)
    got = K.combine2("sum", n)(x, y)
    assert np.isposinf(got[0]) and np.isneginf(got[1])
    got_max = K.combine2("max", n)(x, y)
    assert np.isposinf(got_max[0]) and got_max[1] == 1.0


def test_combine2_jit_and_eager_agree():
    """jit-compiled and eager kernel invocations are bitwise identical."""
    n = 1024
    x = rand((n,), 5)
    y = rand((n,), 6)
    eager = K.combine2("sum", n)(x, y)
    jitted = jax.jit(K.combine2("sum", n))(x, y)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))
